//! Migration executor: move one cached prefix between two MemPools via
//! the paper's 3-step distributed-transfer protocol (§4.3 — allocation,
//! transmission, insertion), with `transfer_with_insert` semantics on
//! the receiver and pin-during-transfer on the donor.
//!
//! Two drivers share this logic:
//!
//! * the **local-halves form** here ([`migrate_prefix`] /
//!   [`execute_plan`]) used by tests and the `fig16_elastic` bench,
//!   where both pools live in one address space and the wire is modeled
//!   by the returned byte/call counts ([`TransferMode::ByRequestAgg`]
//!   keeps the call count at one per token-block);
//! * the **live-server form** (`Msg::MigrateOut` → `Msg::KvMigrate` →
//!   `Msg::MigrateLanded` in [`crate::server`]), where the same steps
//!   run across instance threads over the fabric and the leader applies
//!   the ownership handoff when the receiver acknowledges.

use crate::mempool::{
    GroupList, MemPool, PoolError, Tier, TransferMode,
};

use super::planner::MigrationPlan;

/// What one migration (or a whole plan) actually moved.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct MigrationOutcome {
    pub moved_token_blocks: usize,
    pub moved_tokens: usize,
    /// Modeled wire cost (payload is the KV cache; mode-independent).
    pub wire_bytes: usize,
    /// Modeled network API calls (mode- and layout-dependent).
    pub wire_calls: usize,
}

impl MigrationOutcome {
    pub fn absorb(&mut self, o: &MigrationOutcome) {
        self.moved_token_blocks += o.moved_token_blocks;
        self.moved_tokens += o.moved_tokens;
        self.wire_bytes += o.wire_bytes;
        self.wire_calls += o.wire_calls;
    }
}

/// One exported prefix, ready for the wire (or a direct hand to
/// [`land_prefix`]): the donor half's output.
#[derive(Clone, Debug)]
pub struct ExportedPrefix {
    /// Tokens actually covered (≤ the requested prefix).
    pub tokens: usize,
    /// Allocatable blocks in `payload`.
    pub n_blocks: usize,
    pub payload: Vec<f32>,
}

/// Donor half, shared by the local executor and the live server's
/// `MigrateOut` handler: `match_and_pin` holds the prefix against
/// eviction/swap/expiry while it is read (pin-during-transfer),
/// DRAM-resident blocks are swapped in first (the wire reads HBM), and
/// the blocks are serialized into one payload. The pin is released on
/// every path before returning — once exported, the payload is an
/// independent copy. Returns `None` when the donor holds none of
/// `tokens`.
pub fn export_prefix(
    donor: &mut MemPool,
    tokens: &[u32],
    now: f64,
) -> Result<Option<ExportedPrefix>, PoolError> {
    let m = donor.match_and_pin(tokens, now);
    if m.tokens == 0 {
        return Ok(None);
    }
    let pinned = &tokens[..m.tokens];
    let res = (|| {
        let flat = if m.needs_swap_in() {
            donor.swap_in(&m.flat_addrs())?
        } else {
            m.flat_addrs()
        };
        let payload = donor.export_blocks(&flat)?;
        Ok(ExportedPrefix {
            tokens: m.tokens,
            n_blocks: flat.len(),
            payload,
        })
    })();
    donor.unpin(pinned);
    res.map(Some)
}

/// Does `pool` already index the **entire** token prefix? The
/// idempotency probe for the live server's `KvMigrate` handler (ISSUE
/// 6): a duplicated/retried transfer whose payload already landed must
/// re-ack without importing the blocks twice. Read-only — the match is
/// not pinned and the probe leaves recency untouched beyond the match
/// itself.
pub fn holds_prefix(pool: &mut MemPool, tokens: &[u32], now: f64) -> bool {
    !tokens.is_empty() && pool.match_prefix(tokens, now).tokens >= tokens.len()
}

/// Receiver half, shared by the local executor and the live server's
/// `KvMigrate` handler: allocate on demand (the no-dstAddrList flavor
/// of `transfer` — `import_blocks` makes room in HBM itself), land the
/// payload, and index it under the migrated tokens
/// (`transfer_with_insert`).
pub fn land_prefix(
    receiver: &mut MemPool,
    tokens: &[u32],
    payload: &[f32],
    n_blocks: usize,
    now: f64,
) -> Result<(), PoolError> {
    let landed =
        receiver.import_blocks(payload, n_blocks, None, Tier::Hbm, now)?;
    let per = receiver.geometry().blocks_per_token_block();
    let mut groups = GroupList::default();
    for c in landed.chunks(per) {
        groups.push_group(c);
    }
    receiver.insert_list(tokens, &groups, now)?;
    Ok(())
}

/// Ship the donor's cached prefix of `tokens` into `receiver`: the
/// 3-step allocate → transmit → insert protocol with both halves in one
/// address space. Moves whatever prefix the donor actually holds
/// (possibly shorter than `tokens`, possibly nothing); the caller hands
/// off global-tree ownership for the *moved* span afterwards.
pub fn migrate_prefix(
    donor: &mut MemPool,
    receiver: &mut MemPool,
    tokens: &[u32],
    mode: TransferMode,
    now: f64,
) -> Result<MigrationOutcome, PoolError> {
    let Some(e) = export_prefix(donor, tokens, now)? else {
        return Ok(MigrationOutcome::default());
    };
    land_prefix(receiver, &tokens[..e.tokens], &e.payload, e.n_blocks, now)?;
    let geom = *donor.geometry();
    Ok(MigrationOutcome {
        moved_token_blocks: e.tokens / geom.block_tokens,
        moved_tokens: e.tokens,
        wire_bytes: mode.network_bytes(&geom, e.tokens),
        wire_calls: mode.network_calls(&geom, e.tokens),
    })
}

/// Run every task of a plan against a fleet of local pools (pool index =
/// `InstanceId.0`) — the bench/test harness form of the executor.
pub fn execute_plan(
    plan: &MigrationPlan,
    pools: &mut [MemPool],
    mode: TransferMode,
    now: f64,
) -> Result<MigrationOutcome, PoolError> {
    let mut total = MigrationOutcome::default();
    for t in &plan.tasks {
        let (donor, receiver) =
            two_mut(pools, t.from.0 as usize, t.to.0 as usize);
        let o = migrate_prefix(donor, receiver, &t.tokens, mode, now)?;
        total.absorb(&o);
    }
    Ok(total)
}

/// Two distinct mutable elements of one slice.
fn two_mut<T>(xs: &mut [T], i: usize, j: usize) -> (&mut T, &mut T) {
    assert_ne!(i, j, "donor and receiver must differ");
    if i < j {
        let (a, b) = xs.split_at_mut(j);
        (&mut a[i], &mut b[0])
    } else {
        let (a, b) = xs.split_at_mut(i);
        (&mut b[0], &mut a[j])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mempool::{BlockGeometry, InstanceId};

    fn geom() -> BlockGeometry {
        BlockGeometry {
            block_tokens: 4,
            layers: 2,
            n_heads: 2,
            head_dim: 4,
            aggregated: true,
        }
    }

    fn pool(id: u32, hbm: usize, dram: usize) -> MemPool {
        MemPool::new(InstanceId(id), geom(), hbm, dram, 0.0, true)
    }

    fn toks(n: usize, seed: u32) -> Vec<u32> {
        (0..n as u32).map(|i| i * 7 + seed).collect()
    }

    /// Insert `n` blocks of recognizable data under `tokens`.
    fn seed_prefix(p: &mut MemPool, tokens: &[u32], fill: f32, now: f64) {
        let n = tokens.len() / p.geometry().block_tokens;
        let fpb = p.geometry().floats_per_block();
        let addrs = p.alloc_mem(n, Tier::Hbm).unwrap();
        for (i, &a) in addrs.iter().enumerate() {
            p.write_block(a, &vec![fill + i as f32; fpb]).unwrap();
        }
        p.insert(
            tokens,
            addrs.into_iter().map(|a| vec![a]).collect(),
            now,
        )
        .unwrap();
    }

    #[test]
    fn migrate_moves_data_and_indexes_receiver() {
        let mut donor = pool(0, 8, 0);
        let mut recv = pool(1, 8, 0);
        let t = toks(8, 1);
        seed_prefix(&mut donor, &t, 5.0, 1.0);
        let o = migrate_prefix(
            &mut donor,
            &mut recv,
            &t,
            TransferMode::ByRequestAgg,
            2.0,
        )
        .unwrap();
        assert_eq!(o.moved_token_blocks, 2);
        assert_eq!(o.moved_tokens, 8);
        assert_eq!(o.wire_calls, 2); // agg: one call per token-block
        assert!(o.wire_bytes > 0);
        // Receiver indexed the prefix and the data made it intact.
        let m = recv.match_prefix(&t, 3.0);
        assert_eq!(m.tokens, 8);
        let fpb = recv.geometry().floats_per_block();
        let mut buf = vec![0.0; fpb];
        recv.read_block(m.groups[1][0], &mut buf).unwrap();
        assert_eq!(buf[0], 6.0);
        // Donor keeps its copy (decommission reclaims it) and the pin
        // was released: eviction can take it again.
        assert_eq!(donor.match_prefix(&t, 3.0).tokens, 8);
        assert_eq!(donor.evict(2), 2);
        recv.check_consistency(0).unwrap();
        donor.check_consistency(0).unwrap();
    }

    #[test]
    fn migrate_swaps_in_dram_resident_prefix() {
        let mut donor = pool(0, 4, 4);
        let mut recv = pool(1, 4, 0);
        let t = toks(8, 2);
        seed_prefix(&mut donor, &t, 1.0, 1.0);
        donor.swap_out(2).unwrap();
        assert_eq!(donor.used_blocks(Tier::Dram), 2);
        let o = migrate_prefix(
            &mut donor,
            &mut recv,
            &t,
            TransferMode::ByRequest,
            2.0,
        )
        .unwrap();
        assert_eq!(o.moved_token_blocks, 2);
        // by_request over the discrete math: 2 blocks * 2 * layers.
        assert_eq!(o.wire_calls, 2 * 2 * 2);
        assert_eq!(recv.match_prefix(&t, 3.0).tokens, 8);
        let fpb = recv.geometry().floats_per_block();
        let mut buf = vec![0.0; fpb];
        let m = recv.match_prefix(&t, 3.0);
        recv.read_block(m.groups[0][0], &mut buf).unwrap();
        assert_eq!(buf[0], 1.0);
    }

    #[test]
    fn migrate_partial_and_missing_prefixes() {
        let mut donor = pool(0, 8, 0);
        let mut recv = pool(1, 8, 0);
        let t = toks(12, 3);
        seed_prefix(&mut donor, &t[..8], 1.0, 1.0); // only 2 of 3 blocks
        let o = migrate_prefix(
            &mut donor,
            &mut recv,
            &t,
            TransferMode::ByRequestAgg,
            2.0,
        )
        .unwrap();
        assert_eq!(o.moved_tokens, 8, "moves what the donor holds");
        assert_eq!(recv.match_prefix(&t, 3.0).tokens, 8);
        // Nothing cached at all: a clean no-op.
        let o2 = migrate_prefix(
            &mut donor,
            &mut recv,
            &toks(8, 99),
            TransferMode::ByRequestAgg,
            2.0,
        )
        .unwrap();
        assert_eq!(o2, MigrationOutcome::default());
    }

    #[test]
    fn receiver_duplicates_are_freed_not_leaked() {
        let mut donor = pool(0, 8, 0);
        let mut recv = pool(1, 8, 0);
        let t = toks(8, 4);
        seed_prefix(&mut donor, &t, 1.0, 1.0);
        seed_prefix(&mut recv, &t[..4], 9.0, 1.0); // receiver has block 0
        migrate_prefix(
            &mut donor,
            &mut recv,
            &t,
            TransferMode::ByRequestAgg,
            2.0,
        )
        .unwrap();
        // The shipped copy of block 0 was a duplicate and went back to
        // the allocator: only the original block 0 + the new block 1
        // stay used.
        assert_eq!(recv.used_blocks(Tier::Hbm), 2);
        assert_eq!(recv.match_prefix(&t, 3.0).tokens, 8);
        recv.check_consistency(0).unwrap();
    }

    #[test]
    fn holds_prefix_is_full_prefix_only() {
        let mut p = pool(0, 8, 0);
        let t = toks(12, 5);
        seed_prefix(&mut p, &t[..8], 1.0, 1.0);
        assert!(holds_prefix(&mut p, &t[..8], 2.0));
        assert!(!holds_prefix(&mut p, &t, 2.0), "partial hold is not held");
        assert!(!holds_prefix(&mut p, &[], 2.0));
        // A duplicate land after the probe short-circuits is a no-op at
        // the pool level: usage stays at the original two blocks.
        assert_eq!(p.used_blocks(Tier::Hbm), 2);
    }

    #[test]
    fn execute_plan_routes_tasks_between_pools() {
        use crate::elastic::planner::MigrationTask;
        let mut pools = vec![pool(0, 8, 0), pool(1, 8, 0), pool(2, 8, 0)];
        let ta = toks(8, 1);
        let tb = toks(8, 2);
        seed_prefix(&mut pools[0], &ta, 1.0, 1.0);
        seed_prefix(&mut pools[0], &tb, 2.0, 1.0);
        let plan = MigrationPlan {
            tasks: vec![
                MigrationTask {
                    from: InstanceId(0),
                    to: InstanceId(1),
                    tokens: ta.clone(),
                    blocks: 2,
                },
                MigrationTask {
                    from: InstanceId(0),
                    to: InstanceId(2),
                    tokens: tb.clone(),
                    blocks: 2,
                },
            ],
            planned_blocks: 4,
            ..Default::default()
        };
        let o = execute_plan(
            &plan,
            &mut pools,
            TransferMode::ByRequestAgg,
            2.0,
        )
        .unwrap();
        assert_eq!(o.moved_token_blocks, 4);
        assert_eq!(pools[1].match_prefix(&ta, 3.0).tokens, 8);
        assert_eq!(pools[2].match_prefix(&tb, 3.0).tokens, 8);
    }
}
