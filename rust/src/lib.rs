//! # MemServe
//!
//! A reproduction of *"MemServe: Context Caching for Disaggregated LLM
//! Serving with Elastic Memory Pool"* (Hu et al., 2024) as a three-layer
//! Rust + JAX + Pallas serving framework.
//!
//! Layer map (see DESIGN.md):
//! * [`mempool`] — the elastic memory pool (§4): block allocator, tiers,
//!   token-indexed radix tree, swap, distributed-transfer types.
//! * [`net`] — the simulated NCCL-like fabric instances communicate over.
//! * [`runtime`] — PJRT executor loading AOT HLO artifacts (the `xla`
//!   crate); the only place model compute happens at runtime.
//! * [`engine`] — the inference engine: paged KV, prefill/decode, and the
//!   four disaggregation+caching milestones of §5 (Table 4).
//! * [`scheduler`] — global prompt trees, routing policies, cost model.
//! * [`elastic`] — instance lifecycle, live KV migration planning and
//!   execution, ownership delta protocol (the pool's *elasticity*).
//! * [`replica`] — replicated global scheduler: sequenced delta-log
//!   transport, tree snapshots, follower catch-up and failover.
//! * [`cluster`] — membership, heartbeats, failure handling (§4.4).
//! * [`obs`] — cluster observability: metric registry, request-scoped
//!   tracing, control-plane flight recorder, leader scrape fold.
//! * [`sim`] — discrete-event simulator for request-rate sweeps.
//! * [`workload`] — ShareGPT/LooGLE/ReAct-like synthetic workloads (§8.2).
//! * [`server`] — the live serving assembly (threads + fabric + PJRT).
//! * [`util`], [`config`], [`tokenizer`], [`metrics`] — substrates.

// ISSUE 10: unsafe is *confined*, not forbidden — the PJRT FFI glue in
// `runtime::executor` legitimately needs three Send/Sync impls (raw
// pointer handles into a documented-thread-safe CPU client). That one
// module carries `#[allow(unsafe_code)]` with a SAFETY comment; every
// other module is checked unsafe-free at compile time. `deny` (not
// `forbid`) precisely so the scoped allow stays legal.
#![deny(unsafe_code)]
#![deny(unsafe_op_in_unsafe_fn)]

pub mod cluster;
pub mod config;
pub mod elastic;
pub mod engine;
pub mod mempool;
pub mod metrics;
pub mod net;
pub mod obs;
pub mod replica;
pub mod runtime;
pub mod scheduler;
pub mod server;
pub mod sim;
pub mod tokenizer;
pub mod util;
pub mod workload;
