//! The fused global prompt tree (paper §6, Fig 6 — fleet-scale edition).
//!
//! The seed kept one radix tree per instance and walked **all of them**
//! per request: O(instances × prompt_blocks) on the hottest scheduler
//! path. This module replaces the array with a **single** radix tree over
//! token-blocks whose nodes carry a per-instance ownership bitset, so one
//! walk yields the matched prefix length for *every* prefill-capable
//! instance simultaneously — routing is O(prompt_blocks) regardless of
//! cluster size (the per-node work is a handful of u64 word ops).
//!
//! # Internals
//!
//! * **Ownership bitsets + stamp lists.** Each node stores `owners`
//!   (`u64` words, grown lazily as instances register) and `stamps`, a
//!   slot-sorted `Vec<(slot, last_insert)>` mirroring the set bits.
//!   [`FusedPromptTree::record`] walks the insert path and stamps every
//!   node on it, so ownership is *prefix-closed*: a node owned by
//!   instance i implies its parent is owned by i, and the parent's stamp
//!   is ≥ the child's. The routing walk exploits closure: it keeps an
//!   `alive` word set (instances owning the whole path so far), ANDs it
//!   with each node's owners, and records drop-outs at their depth.
//! * **Heap-driven TTL.** The global tree only learns about inserts,
//!   never local evictions, so entries carry a TTL (paper §6 Discussion).
//!   The seed re-scanned every node per expiry fixpoint iteration; here
//!   every record pushes a lazy `(stamp, node, slot)` entry onto a
//!   min-heap and [`FusedPromptTree::expire`] pops expired entries in
//!   O(log n) each, validating against the node's current stamp (stale
//!   entries from re-records are discarded). Stamp monotonicity up the
//!   tree means children expire no later than parents, so clearing bits
//!   heap-order preserves prefix closure; a node whose last owner leaves
//!   is unlinked and its (ownerless) subtree reclaimed.
//! * **Incremental cached-block counters.** Per-slot `cached_blocks` is
//!   maintained on record/expire/remove instead of re-deriving from the
//!   tree, keeping the router's load signals O(1).
//! * **Read-only matching.** The routing walk mutates nothing but two
//!   reusable scratch buffers — global trees are address-free, so there
//!   is no LRU to maintain and bumping last-access on every route (what
//!   the seed's shared `RadixIndex` did) is pure waste; staleness is
//!   governed by *insert* recency alone. [`FusedPromptTree::match_into`]
//!   fills a caller-provided vector: zero allocation at steady state.
//!
//! The seed layout survives as
//! [`crate::scheduler::prompt_tree_ref::RefGlobalPromptTrees`] for
//! differential testing and as the benchmark baseline
//! (`benches/fig15_scheduler.rs` sweeps instance counts against it).

use std::collections::{BTreeMap, BinaryHeap, HashMap};
use std::hash::BuildHasherDefault;

use crate::elastic::delta::DeltaEvent;
use crate::mempool::index::{block_fingerprint, FpHasher};
use crate::mempool::InstanceId;
use crate::scheduler::prompt_tree::InstanceKind;

/// A maximal prefix one instance is believed to cache (see
/// [`FusedPromptTree::owned_paths`]): the migration planner's unit of
/// work.
#[derive(Clone, Debug, PartialEq)]
pub struct OwnedPrefix {
    pub tokens: Vec<u32>,
    /// Last-insert stamp of the path's deepest node — the hotness
    /// signal (matching never bumps stamps).
    pub last_insert: f64,
    /// Depth in token-blocks (`tokens.len() / block_tokens`).
    pub blocks: usize,
}

/// Cold-instance ranking key for [`FusedPromptTree::match_into_capped`]:
/// lexicographic `(primary, secondary, tertiary)`, smaller = better.
/// The caller composes it to mirror its policy's exact ordering over
/// zero-match candidates (e.g. `(expected cost, queued tokens, session
/// hash)` for the prompt-tree policy), so capping the emission provably
/// cannot change the routing decision: every positive-match instance is
/// emitted, and the best cold instance by this key is in the sample.
pub type ColdRank = (f64, u64, u64);

/// Lexicographic [`ColdRank`] comparison with a final ascending-id tie
/// break — the one ordering every cold-sampling path (the tree's
/// [`FusedPromptTree::match_into_capped`] and the router's load-book
/// selection) must share so capped emission cannot change a decision.
#[inline]
pub fn cold_rank_cmp(
    a: &(ColdRank, InstanceId),
    b: &(ColdRank, InstanceId),
) -> std::cmp::Ordering {
    a.0 .0
        .total_cmp(&b.0 .0)
        .then(a.0 .1.cmp(&b.0 .1))
        .then(a.0 .2.cmp(&b.0 .2))
        .then(a.1.cmp(&b.1))
}

/// Sentinel for "no node" in intrusive sibling links.
const NONE: usize = usize::MAX;

const ROOT: usize = 0;

type FpMap = HashMap<u64, usize, BuildHasherDefault<FpHasher>>;

#[inline]
fn word_bit(slot: u32) -> (usize, u64) {
    ((slot / 64) as usize, 1u64 << (slot % 64))
}

#[inline]
fn test_bit(words: &[u64], slot: u32) -> bool {
    let (w, m) = word_bit(slot);
    words.get(w).is_some_and(|x| x & m != 0)
}

struct Slot {
    kind: InstanceKind,
    /// Token-blocks this instance is believed to cache (incremental).
    cached_blocks: usize,
    live: bool,
    /// Draining instances (lifecycle `Active → Draining`) are excluded
    /// from the routing walk but stay matchable via [`FusedPromptTree::
    /// match_one`] — they keep serving as migration donors until
    /// decommission.
    draining: bool,
}

struct FNode {
    /// Edge label from the parent; length is a multiple of
    /// `block_tokens` (root excepted: empty edge).
    edge: Vec<u32>,
    /// Children keyed by the fingerprint of the child's first edge
    /// block; fingerprint collisions chain through `next_sibling`.
    children: FpMap,
    next_sibling: usize,
    parent: usize,
    /// Ownership bitset over instance slots (lazily grown; short = 0s).
    owners: Vec<u64>,
    /// Slot-sorted (slot, last-insert stamp) pairs — exactly the set
    /// bits of `owners`.
    stamps: Vec<(u32, f64)>,
    /// Bumped on node release so recycled indices invalidate old heap
    /// entries.
    gen: u64,
    valid: bool,
}

impl FNode {
    fn blocks(&self, block_tokens: usize) -> usize {
        self.edge.len() / block_tokens
    }
}

/// Lazy min-heap entry: (node, slot) expires at `stamp + ttl`.
#[derive(Debug, PartialEq)]
struct ExpireEntry {
    stamp: f64,
    node: usize,
    slot: u32,
    gen: u64,
}

impl Eq for ExpireEntry {}

impl Ord for ExpireEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Reversed: BinaryHeap is a max-heap, we pop the oldest stamp
        // first; ties break deterministically by (node, slot).
        other
            .stamp
            .partial_cmp(&self.stamp)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| other.node.cmp(&self.node))
            .then_with(|| other.slot.cmp(&self.slot))
    }
}

impl PartialOrd for ExpireEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// One radix tree for the whole fleet; see module docs.
pub struct FusedPromptTree {
    nodes: Vec<FNode>,
    free_list: Vec<usize>,
    block_tokens: usize,
    /// TTL in seconds; 0 disables expiry.
    ttl: f64,
    /// Instance registry: slot-indexed info + id→slot map (BTreeMap so
    /// candidate emission is in ascending InstanceId order, matching the
    /// seed's per-instance `BTreeMap` iteration).
    slots: Vec<Slot>,
    by_id: BTreeMap<InstanceId, u32>,
    free_slots: Vec<u32>,
    /// Bit per slot whose instance runs prefill (routing candidates).
    prefill_mask: Vec<u64>,
    /// Count of routing candidates (prefill-capable, live, not
    /// draining) — maintained by add/remove/[`Self::set_draining`] so
    /// the router's capped-emission gate is O(1) per route.
    routable: usize,
    /// `prefill_mask` minus draining slots — the set the routing walk
    /// actually considers. Maintained by add/remove/[`Self::
    /// set_draining`] so `match_into` pays nothing extra per route.
    route_mask: Vec<u64>,
    /// TTL heap (lazy deletion, validated against node stamps at pop).
    heap: BinaryHeap<ExpireEntry>,
    /// Live (node, instance) ownership pairs — heap compaction bound.
    owner_pairs: usize,
    /// Routing-walk scratch (reused; no allocation at steady state).
    alive: Vec<u64>,
    matched: Vec<usize>,
    /// Capped-emission scratch: cold-candidate ranks and the selected
    /// cold sample (reused; see [`Self::match_into_capped`]).
    cold_buf: Vec<(ColdRank, InstanceId)>,
    cold_sel: Vec<InstanceId>,
    /// Mask applied to child fingerprints; tests shrink it to force
    /// collision chains.
    fp_mask: u64,
}

impl FusedPromptTree {
    pub fn new(block_tokens: usize, ttl: f64) -> Self {
        assert!(block_tokens > 0);
        FusedPromptTree {
            nodes: vec![FNode {
                edge: vec![],
                children: FpMap::default(),
                next_sibling: NONE,
                parent: ROOT,
                owners: vec![],
                stamps: vec![],
                gen: 0,
                valid: true,
            }],
            free_list: vec![],
            block_tokens,
            ttl,
            slots: vec![],
            by_id: BTreeMap::new(),
            free_slots: vec![],
            prefill_mask: vec![],
            routable: 0,
            route_mask: vec![],
            heap: BinaryHeap::new(),
            owner_pairs: 0,
            alive: vec![],
            matched: vec![],
            cold_buf: vec![],
            cold_sel: vec![],
            fp_mask: u64::MAX,
        }
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    /// Test hook: mask child fingerprints to force collision chains.
    /// Must be called before any record.
    #[doc(hidden)]
    pub fn set_fingerprint_mask(&mut self, mask: u64) {
        assert!(
            self.nodes[ROOT].children.is_empty() && self.free_list.is_empty(),
            "fingerprint mask must be set before any record"
        );
        self.fp_mask = mask;
    }

    // ------------------------------------------------------------------
    // Instance registry
    // ------------------------------------------------------------------

    pub fn add_instance(&mut self, id: InstanceId, kind: InstanceKind) {
        if self.by_id.contains_key(&id) {
            // Re-registration replaces the old view (seed semantics:
            // `BTreeMap::insert` dropped the old tree).
            self.remove_instance(id);
        }
        let slot = match self.free_slots.pop() {
            Some(s) => {
                self.slots[s as usize] = Slot {
                    kind,
                    cached_blocks: 0,
                    live: true,
                    draining: false,
                };
                s
            }
            None => {
                self.slots.push(Slot {
                    kind,
                    cached_blocks: 0,
                    live: true,
                    draining: false,
                });
                (self.slots.len() - 1) as u32
            }
        };
        self.by_id.insert(id, slot);
        let (w, m) = word_bit(slot);
        if self.prefill_mask.len() <= w {
            self.prefill_mask.resize(w + 1, 0);
            self.route_mask.resize(w + 1, 0);
        }
        if kind.runs_prefill() {
            self.prefill_mask[w] |= m;
            self.route_mask[w] |= m;
            self.routable += 1;
        }
    }

    /// Drop a failed/removed instance (paper §4.4: membership change):
    /// clear its ownership everywhere and reclaim subtrees nobody else
    /// caches. O(nodes) — membership changes are rare and off the
    /// request path.
    pub fn remove_instance(&mut self, id: InstanceId) {
        let Some(slot) = self.by_id.remove(&id) else {
            return;
        };
        let (w, m) = word_bit(slot);
        for i in 0..self.nodes.len() {
            if i == ROOT || !self.nodes[i].valid {
                continue;
            }
            let n = &mut self.nodes[i];
            if let Ok(j) = n.stamps.binary_search_by_key(&slot, |s| s.0) {
                n.stamps.remove(j);
                n.owners[w] &= !m;
                self.owner_pairs -= 1;
            }
        }
        if self.slot_routable(slot) {
            self.routable -= 1;
        }
        let s = &mut self.slots[slot as usize];
        s.live = false;
        s.cached_blocks = 0;
        s.draining = false;
        self.prefill_mask[w] &= !m;
        self.route_mask[w] &= !m;
        self.free_slots.push(slot);
        self.prune_ownerless();
    }

    /// Toggle routing visibility for a draining instance: its bit leaves
    /// the routing walk's alive set and `match_into` stops emitting it,
    /// but its ownership (and [`Self::match_one`]) survives untouched so
    /// migration can read and hand off its prefixes with no window in
    /// which routing sees them as lost.
    pub fn set_draining(&mut self, id: InstanceId, draining: bool) {
        let Some(&slot) = self.by_id.get(&id) else {
            return;
        };
        let s = &mut self.slots[slot as usize];
        let flipped = s.draining != draining;
        s.draining = draining;
        let runs_prefill = s.kind.runs_prefill();
        let (w, m) = word_bit(slot);
        if draining {
            self.route_mask[w] &= !m;
            if flipped && runs_prefill {
                self.routable -= 1;
            }
        } else if runs_prefill {
            self.route_mask[w] |= m;
            if flipped {
                self.routable += 1;
            }
        }
    }

    pub fn is_draining(&self, id: InstanceId) -> bool {
        self.by_id
            .get(&id)
            .is_some_and(|&s| self.slots[s as usize].draining)
    }

    /// Registered instances in ascending id order.
    pub fn instances(
        &self,
    ) -> impl Iterator<Item = (InstanceId, InstanceKind)> + '_ {
        self.by_id
            .iter()
            .map(move |(&id, &s)| (id, self.slots[s as usize].kind))
    }

    pub fn instance_count(&self) -> usize {
        self.by_id.len()
    }

    /// The one routing-candidate predicate every emission path shares.
    #[inline]
    fn slot_routable(&self, slot: u32) -> bool {
        let s = &self.slots[slot as usize];
        s.live && s.kind.runs_prefill() && !s.draining
    }

    /// Is `id` a routing candidate (registered, prefill-capable, not
    /// draining)? Exactly the predicate `match_into` emits by.
    pub fn is_route_candidate(&self, id: InstanceId) -> bool {
        self.by_id
            .get(&id)
            .is_some_and(|&slot| self.slot_routable(slot))
    }

    /// Number of routing candidates (the fleet `match_into` emits) —
    /// an O(1) maintained counter.
    pub fn routable_count(&self) -> usize {
        self.routable
    }

    pub fn kind_of(&self, id: InstanceId) -> Option<InstanceKind> {
        self.by_id.get(&id).map(|&s| self.slots[s as usize].kind)
    }

    /// Total cached token-blocks believed to exist on `id` — an O(1)
    /// counter maintained incrementally on record/expire/remove.
    pub fn cached_blocks(&self, id: InstanceId) -> usize {
        self.by_id
            .get(&id)
            .map(|&s| self.slots[s as usize].cached_blocks)
            .unwrap_or(0)
    }

    /// Live node count (excluding root) — diagnostics.
    pub fn node_count(&self) -> usize {
        self.nodes.len() - 1 - self.free_list.len()
    }

    // ------------------------------------------------------------------
    // Node plumbing (fingerprint-keyed children, PR 1 layout)
    // ------------------------------------------------------------------

    #[inline]
    fn fp(&self, block: &[u32]) -> u64 {
        block_fingerprint(block) & self.fp_mask
    }

    fn alloc_node(&mut self, mut node: FNode) -> usize {
        if let Some(i) = self.free_list.pop() {
            // Continue the slot's gen sequence so stale heap entries can
            // never alias the new node.
            node.gen = self.nodes[i].gen + 1;
            self.nodes[i] = node;
            i
        } else {
            self.nodes.push(node);
            self.nodes.len() - 1
        }
    }

    fn release_node(&mut self, idx: usize) {
        debug_assert_ne!(idx, ROOT);
        let n = &mut self.nodes[idx];
        n.valid = false;
        n.gen += 1;
        n.children.clear();
        n.edge.clear();
        n.owners.clear();
        n.stamps.clear();
        n.next_sibling = NONE;
        self.free_list.push(idx);
    }

    /// Find `parent`'s child whose edge starts with the block `key`.
    fn find_child(&self, parent: usize, key: &[u32]) -> Option<usize> {
        let fp = self.fp(key);
        let mut cand = self.nodes[parent].children.get(&fp).copied();
        while let Some(i) = cand {
            if &self.nodes[i].edge[..self.block_tokens] == key {
                return Some(i);
            }
            let next = self.nodes[i].next_sibling;
            cand = if next == NONE { None } else { Some(next) };
        }
        None
    }

    fn attach_child(&mut self, parent: usize, child: usize) {
        let fp = self.fp(&self.nodes[child].edge[..self.block_tokens]);
        let prev = self.nodes[parent].children.insert(fp, child);
        self.nodes[child].next_sibling = prev.unwrap_or(NONE);
    }

    fn detach_child(&mut self, parent: usize, child: usize) {
        let fp = self.fp(&self.nodes[child].edge[..self.block_tokens]);
        let head = self.nodes[parent].children[&fp];
        if head == child {
            let next = self.nodes[child].next_sibling;
            if next == NONE {
                self.nodes[parent].children.remove(&fp);
            } else {
                *self.nodes[parent].children.get_mut(&fp).unwrap() = next;
            }
        } else {
            let mut prev = head;
            loop {
                let next = self.nodes[prev].next_sibling;
                if next == NONE {
                    debug_assert!(false, "child not linked under parent");
                    break;
                }
                if next == child {
                    self.nodes[prev].next_sibling =
                        self.nodes[child].next_sibling;
                    break;
                }
                prev = next;
            }
        }
        self.nodes[child].next_sibling = NONE;
    }

    fn child_indices(&self, node: usize) -> Vec<usize> {
        let mut out = vec![];
        for &head in self.nodes[node].children.values() {
            let mut c = head;
            while c != NONE {
                out.push(c);
                c = self.nodes[c].next_sibling;
            }
        }
        out
    }

    /// Longest common prefix of `edge` and `rest`, rounded down to a
    /// block boundary.
    fn common_block_prefix(&self, edge: &[u32], rest: &[u32]) -> usize {
        let mut i = 0;
        let max = edge.len().min(rest.len());
        while i < max && edge[i] == rest[i] {
            i += 1;
        }
        i - i % self.block_tokens
    }

    /// Split `node`'s edge at `at` tokens (block-aligned): the node
    /// keeps the head; a new child gets the tail + original children.
    /// Owners and stamps are duplicated onto the tail (each owner's
    /// recorded span covered the whole edge), which creates new
    /// (node, instance) pairs: heap entries are pushed for them.
    /// Returns the tail node's index.
    fn split(&mut self, node: usize, at: usize) -> usize {
        debug_assert!(at % self.block_tokens == 0 && at > 0);
        let tail_edge = self.nodes[node].edge.split_off(at);
        let tail_children = std::mem::take(&mut self.nodes[node].children);
        let owners = self.nodes[node].owners.clone();
        let stamps = self.nodes[node].stamps.clone();
        let tail = self.alloc_node(FNode {
            edge: tail_edge,
            children: tail_children,
            next_sibling: NONE,
            parent: node,
            owners,
            stamps,
            gen: 0,
            valid: true,
        });
        for gc in self.child_indices(tail) {
            self.nodes[gc].parent = tail;
        }
        self.attach_child(node, tail);
        // Per-slot block counts are unchanged (the edge's blocks are now
        // split across two owned nodes), but the pair count grows.
        self.owner_pairs += self.nodes[tail].stamps.len();
        if self.ttl > 0.0 {
            let gen = self.nodes[tail].gen;
            let pairs = self.nodes[tail].stamps.clone();
            for (slot, stamp) in pairs {
                self.heap.push(ExpireEntry {
                    stamp,
                    node: tail,
                    slot,
                    gen,
                });
            }
            self.maybe_compact_heap();
        }
        tail
    }

    // ------------------------------------------------------------------
    // Record (Fig 6 response path)
    // ------------------------------------------------------------------

    /// Record that `instance` now caches `tokens` (block-truncated).
    pub fn record(&mut self, instance: InstanceId, tokens: &[u32], now: f64) {
        let Some(&slot) = self.by_id.get(&instance) else {
            return;
        };
        let bt = self.block_tokens;
        let usable = tokens.len() - tokens.len() % bt;
        let tokens = &tokens[..usable];
        let mut cur = ROOT;
        let mut pos = 0;
        while pos < usable {
            let key = &tokens[pos..pos + bt];
            match self.find_child(cur, key) {
                None => {
                    // Attach the whole remainder as one new leaf.
                    let leaf = self.alloc_node(FNode {
                        edge: tokens[pos..].to_vec(),
                        children: FpMap::default(),
                        next_sibling: NONE,
                        parent: cur,
                        owners: vec![],
                        stamps: vec![],
                        gen: 0,
                        valid: true,
                    });
                    self.attach_child(cur, leaf);
                    self.stamp_owner(leaf, slot, now);
                    return;
                }
                Some(child) => {
                    let common = self.common_block_prefix(
                        &self.nodes[child].edge,
                        &tokens[pos..],
                    );
                    debug_assert!(
                        common >= bt,
                        "block-keyed child must share its first block"
                    );
                    if common < self.nodes[child].edge.len() {
                        self.split(child, common);
                    }
                    self.stamp_owner(child, slot, now);
                    cur = child;
                    pos += common;
                }
            }
        }
    }

    /// Mark `slot` as owning `node` as of `now`: set the bit, refresh
    /// the stamp, maintain counters, and queue the TTL entry.
    fn stamp_owner(&mut self, node: usize, slot: u32, now: f64) {
        let blocks = self.nodes[node].blocks(self.block_tokens);
        let (w, m) = word_bit(slot);
        let n = &mut self.nodes[node];
        if n.owners.len() <= w {
            n.owners.resize(w + 1, 0);
        }
        let newly = n.owners[w] & m == 0;
        n.owners[w] |= m;
        match n.stamps.binary_search_by_key(&slot, |s| s.0) {
            Ok(i) => n.stamps[i].1 = now,
            Err(i) => n.stamps.insert(i, (slot, now)),
        }
        let gen = n.gen;
        if newly {
            self.owner_pairs += 1;
            self.slots[slot as usize].cached_blocks += blocks;
        }
        if self.ttl > 0.0 {
            self.heap.push(ExpireEntry {
                stamp: now,
                node,
                slot,
                gen,
            });
            self.maybe_compact_heap();
        }
    }

    // ------------------------------------------------------------------
    // Match (the one-walk scheduling path)
    // ------------------------------------------------------------------

    /// Matched prefix length (tokens) of `tokens` on every routable
    /// (prefill-capable, non-draining) instance, in ascending
    /// instance-id order, written into `out` (cleared first). One tree
    /// walk for the whole fleet; mutates only internal scratch — no
    /// LRU/stamp bumping, no allocation once scratch has warmed up.
    /// Draining instances are invisible here (never candidates, never
    /// donors); their data stays reachable via [`Self::match_one`].
    pub fn match_into(
        &mut self,
        tokens: &[u32],
        out: &mut Vec<(InstanceId, usize)>,
    ) {
        self.route_walk(tokens);
        out.clear();
        for (&id, &slot) in self.by_id.iter() {
            if self.slot_routable(slot) {
                out.push((id, self.matched[slot as usize]));
            }
        }
    }

    /// Split-phase form of the match: run the routing walk only, leaving
    /// each instance's matched length readable via [`Self::walked_len`]
    /// until the next walk. Between [`Self::walk`] and
    /// [`Self::emit_walked`] the router consults its load-ordered book
    /// to pick the cold sample in O(cold_cap log instances) instead of
    /// ranking every zero-match instance.
    pub fn walk(&mut self, tokens: &[u32]) {
        self.route_walk(tokens);
    }

    /// Matched length of `id` from the last [`Self::walk`] (0 when
    /// unknown or not walked).
    pub fn walked_len(&self, id: InstanceId) -> usize {
        self.by_id
            .get(&id)
            .and_then(|&slot| self.matched.get(slot as usize).copied())
            .unwrap_or(0)
    }

    /// Emit the last walk's results: every routable instance with a
    /// positive match plus the listed cold instances (`cold_sorted`
    /// must be ascending), in ascending instance-id order — exactly the
    /// emission shape of [`Self::match_into_capped`].
    pub fn emit_walked(
        &self,
        out: &mut Vec<(InstanceId, usize)>,
        cold_sorted: &[InstanceId],
    ) {
        out.clear();
        for (&id, &slot) in self.by_id.iter() {
            if !self.slot_routable(slot) {
                continue;
            }
            let m = self.matched.get(slot as usize).copied().unwrap_or(0);
            if m > 0 || cold_sorted.binary_search(&id).is_ok() {
                out.push((id, m));
            }
        }
    }

    /// [`Self::match_into`] with capped emission for large fleets: every
    /// instance with a **positive** match is emitted (each at the depth
    /// of the deepest owned node on the prompt's path — these are
    /// bounded by the owners of the matched path, not by fleet size),
    /// plus at most `cold_cap` zero-match instances — the best-ranked
    /// ones by `cold_rank` (the caller's least-loaded ordering; see
    /// [`ColdRank`]). At ~1k instances this removes the dominant
    /// per-route cost — materializing and policy-scanning ~1k
    /// `(InstanceId, matched)` pairs of which all but a handful are
    /// zero — while leaving the decision of any load-monotone policy
    /// exactly unchanged: the winner is either warm (always emitted) or
    /// the rank-minimal cold instance (always sampled). Falls back to
    /// full emission when the routable fleet fits in `cold_cap`.
    /// Emission stays in ascending instance-id order.
    pub fn match_into_capped(
        &mut self,
        tokens: &[u32],
        out: &mut Vec<(InstanceId, usize)>,
        cold_cap: usize,
        cold_rank: &mut dyn FnMut(InstanceId) -> ColdRank,
    ) {
        self.route_walk(tokens);
        out.clear();
        // Decide the fallback BEFORE paying for any rank evaluation
        // (each is a loads lookup + cost-model call at the router):
        // a routable fleet that fits in the cap emits everything.
        if self.routable_count() <= cold_cap {
            for (&id, &slot) in self.by_id.iter() {
                if self.slot_routable(slot) {
                    out.push((id, self.matched[slot as usize]));
                }
            }
            return;
        }
        // Rank the cold (zero-match) routable instances.
        self.cold_buf.clear();
        for (&id, &slot) in self.by_id.iter() {
            if self.slot_routable(slot) && self.matched[slot as usize] == 0
            {
                self.cold_buf.push((cold_rank(id), id));
            }
        }
        // Keep the `cold_cap` best-ranked cold instances (O(n) select,
        // then sort only the sample). cap 0 = warm-only emission.
        if cold_cap == 0 {
            self.cold_buf.clear();
        } else if self.cold_buf.len() > cold_cap {
            self.cold_buf
                .select_nth_unstable_by(cold_cap - 1, cold_rank_cmp);
            self.cold_buf.truncate(cold_cap);
        }
        self.cold_sel.clear();
        self.cold_sel.extend(self.cold_buf.iter().map(|&(_, id)| id));
        self.cold_sel.sort_unstable();
        let cold = std::mem::take(&mut self.cold_sel);
        self.emit_walked(out, &cold);
        self.cold_sel = cold;
    }

    /// The shared routing walk: fills `self.matched[slot]` with each
    /// routable instance's matched prefix length. One tree walk ANDing
    /// the `alive` word-set per node; drop-outs record their depth.
    fn route_walk(&mut self, tokens: &[u32]) {
        let words = self.route_mask.len();
        self.alive.clear();
        self.alive.extend_from_slice(&self.route_mask);
        self.matched.clear();
        self.matched.resize(self.slots.len(), 0);
        let bt = self.block_tokens;
        let mut cur = ROOT;
        let mut pos = 0;
        loop {
            if pos + bt > tokens.len() {
                break;
            }
            let Some(child) = self.find_child(cur, &tokens[pos..pos + bt])
            else {
                break;
            };
            let common = self.common_block_prefix(
                &self.nodes[child].edge,
                &tokens[pos..],
            );
            debug_assert!(common >= bt);
            // Instances not owning this node stop matching here; the
            // rest own its whole edge (ownership covers whole nodes).
            let mut any = 0u64;
            for w in 0..words {
                let ow = self.nodes[child].owners.get(w).copied().unwrap_or(0);
                let a = self.alive[w];
                let mut dropped = a & !ow;
                while dropped != 0 {
                    let b = dropped.trailing_zeros() as usize;
                    self.matched[w * 64 + b] = pos;
                    dropped &= dropped - 1;
                }
                self.alive[w] = a & ow;
                any |= self.alive[w];
            }
            pos += common;
            if any == 0 {
                break; // nobody alive: the survivors flush is a no-op
            }
            if common < self.nodes[child].edge.len() {
                break; // partial edge match ends the walk
            }
            cur = child;
        }
        // Instances alive through the whole walk matched `pos` tokens.
        for w in 0..words {
            let mut a = self.alive[w];
            while a != 0 {
                let b = a.trailing_zeros() as usize;
                self.matched[w * 64 + b] = pos;
                a &= a - 1;
            }
        }
    }

    /// Matched prefix on one specific instance (read-only; used for
    /// D-side incremental-transfer decisions).
    pub fn match_one(&self, id: InstanceId, tokens: &[u32]) -> usize {
        let Some(&slot) = self.by_id.get(&id) else {
            return 0;
        };
        let bt = self.block_tokens;
        let mut cur = ROOT;
        let mut pos = 0;
        loop {
            if pos + bt > tokens.len() {
                break;
            }
            let Some(child) = self.find_child(cur, &tokens[pos..pos + bt])
            else {
                break;
            };
            if !test_bit(&self.nodes[child].owners, slot) {
                break;
            }
            let common = self.common_block_prefix(
                &self.nodes[child].edge,
                &tokens[pos..],
            );
            pos += common;
            if common < self.nodes[child].edge.len() {
                break;
            }
            cur = child;
        }
        pos
    }

    // ------------------------------------------------------------------
    // Ownership deltas (elasticity: drain / migration / honest eviction)
    // ------------------------------------------------------------------

    /// Apply one ownership delta event (see [`crate::elastic::delta`]).
    /// This is the single entry point migration and membership flow
    /// through, and the log-replay interface a future replicated GS
    /// would consume. A [`DeltaEvent::Handoff`] grants the receiver
    /// before retiring the donor inside one call, so routing never
    /// observes the prefix as lost mid-migration.
    pub fn apply_delta(&mut self, ev: &DeltaEvent) {
        match ev {
            DeltaEvent::Join { instance, kind } => {
                self.add_instance(*instance, *kind);
            }
            DeltaEvent::Leave { instance } => self.remove_instance(*instance),
            DeltaEvent::Record {
                instance,
                tokens,
                now,
            } => self.record(*instance, tokens, *now),
            DeltaEvent::Expire { instance, prefix } => {
                self.release_prefix(*instance, prefix);
            }
            DeltaEvent::Handoff {
                from,
                to,
                tokens,
                now,
            } => {
                // Sub-block handoffs carry nothing (and an empty prefix
                // would mean "release everything" to the donor). A
                // receiver no longer registered (e.g. it failed between
                // the ack being sent and processed) must not retire the
                // donor's claim either — the grant half would no-op and
                // the prefix would vanish from routing.
                if tokens.len() < self.block_tokens
                    || !self.by_id.contains_key(to)
                {
                    return;
                }
                self.record(*to, tokens, *now);
                self.release_prefix(*from, tokens);
            }
            DeltaEvent::SetDraining { instance, draining } => {
                self.set_draining(*instance, *draining);
            }
        }
    }

    /// `id` no longer caches `prefix` (block-truncated) nor any
    /// extension of it; proper prefixes and sibling branches survive.
    /// An empty `prefix` clears the instance's entire view. This is the
    /// [`DeltaEvent::Expire`] primitive and the donor half of a handoff;
    /// a no-op when the instance does not cache the full prefix (prefix
    /// closure: then it owns nothing at or under it either).
    pub fn release_prefix(&mut self, id: InstanceId, prefix: &[u32]) {
        let Some(&slot) = self.by_id.get(&id) else {
            return;
        };
        let bt = self.block_tokens;
        let usable = prefix.len() - prefix.len() % bt;
        if usable == 0 {
            for c in self.child_indices(ROOT) {
                self.clear_owner_subtree(c, slot);
            }
            return;
        }
        let prefix = &prefix[..usable];
        let mut cur = ROOT;
        let mut pos = 0;
        loop {
            let Some(child) = self.find_child(cur, &prefix[pos..pos + bt])
            else {
                return;
            };
            if !test_bit(&self.nodes[child].owners, slot) {
                return;
            }
            let common = self.common_block_prefix(
                &self.nodes[child].edge,
                &prefix[pos..],
            );
            debug_assert!(common >= bt);
            pos += common;
            if pos == usable {
                // `child` holds the prefix's last block at edge offset
                // `common - bt`: split there so the earlier blocks stay
                // owned, then clear `slot` from the tail downward.
                let target = if common > bt {
                    self.split(child, common - bt)
                } else {
                    child
                };
                self.clear_owner_subtree(target, slot);
                return;
            }
            if common < self.nodes[child].edge.len() {
                return; // diverged before the boundary
            }
            cur = child;
        }
    }

    /// Remove `slot`'s ownership from the whole subtree rooted at
    /// `node`. Prefix closure bounds the walk: a node not owned by
    /// `slot` has no owned descendants. Subtrees left ownerless are
    /// unlinked and reclaimed (their pending TTL heap entries die with
    /// the stamp removal / gen bump).
    fn clear_owner_subtree(&mut self, node: usize, slot: u32) {
        if !test_bit(&self.nodes[node].owners, slot) {
            return;
        }
        let blocks = self.nodes[node].blocks(self.block_tokens);
        let (w, m) = word_bit(slot);
        let n = &mut self.nodes[node];
        let i = n
            .stamps
            .binary_search_by_key(&slot, |s| s.0)
            .expect("owners/stamps in sync");
        n.stamps.remove(i);
        n.owners[w] &= !m;
        self.owner_pairs -= 1;
        self.slots[slot as usize].cached_blocks -= blocks;
        for c in self.child_indices(node) {
            self.clear_owner_subtree(c, slot);
        }
        if self.nodes[node].stamps.is_empty() {
            // Last owner gone; ownerless children already reclaimed
            // themselves in the recursion (closure), so this drops only
            // the node itself.
            let parent = self.nodes[node].parent;
            self.detach_child(parent, node);
            self.drop_subtree(node);
        }
    }

    /// The maximal prefixes `id` is believed to cache — one entry per
    /// deepest owned path, with the tail node's last-insert stamp and
    /// total depth in token-blocks. This is the migration planner's
    /// donor inventory; sorted by tokens so the plan is deterministic
    /// regardless of child-map iteration order.
    pub fn owned_paths(&self, id: InstanceId) -> Vec<OwnedPrefix> {
        let Some(&slot) = self.by_id.get(&id) else {
            return vec![];
        };
        let mut out = vec![];
        let mut prefix = vec![];
        self.owned_paths_rec(ROOT, slot, &mut prefix, &mut out);
        out.sort_by(|a, b| a.tokens.cmp(&b.tokens));
        out
    }

    fn owned_paths_rec(
        &self,
        node: usize,
        slot: u32,
        prefix: &mut Vec<u32>,
        out: &mut Vec<OwnedPrefix>,
    ) {
        let mut deepest = true;
        for c in self.child_indices(node) {
            if test_bit(&self.nodes[c].owners, slot) {
                deepest = false;
                prefix.extend_from_slice(&self.nodes[c].edge);
                self.owned_paths_rec(c, slot, prefix, out);
                prefix.truncate(prefix.len() - self.nodes[c].edge.len());
            }
        }
        if deepest && node != ROOT {
            let n = &self.nodes[node];
            let i = n
                .stamps
                .binary_search_by_key(&slot, |s| s.0)
                .expect("owned node has a stamp");
            out.push(OwnedPrefix {
                tokens: prefix.clone(),
                last_insert: n.stamps[i].1,
                blocks: prefix.len() / self.block_tokens,
            });
        }
    }

    /// Every `(instance, token-path, last-insert stamp)` ownership pair
    /// in the tree — one entry per (node, instance), with the full token
    /// path to the node. This is the replica-snapshot source
    /// ([`crate::replica::snapshot`]): replaying the entries as `Record`
    /// deltas in **ascending stamp order** reconstructs the exact
    /// ownership *and* stamp state (a record stamps its whole path, and
    /// stamps are monotone up the tree, so each node's own entry —
    /// carrying the path maximum — lands last). Unlike
    /// [`Self::owned_paths`] (maximal paths only, the migration
    /// planner's unit), interior stamps are preserved, which is what
    /// makes a snapshot-restored replica's TTL expiry bit-identical to a
    /// log-replaying one. Order is unspecified; callers sort.
    pub fn ownership_entries(&self) -> Vec<(InstanceId, Vec<u32>, f64)> {
        let mut slot_ids: Vec<Option<InstanceId>> =
            vec![None; self.slots.len()];
        for (&id, &slot) in &self.by_id {
            slot_ids[slot as usize] = Some(id);
        }
        let mut out = vec![];
        let mut prefix = vec![];
        self.ownership_entries_rec(ROOT, &slot_ids, &mut prefix, &mut out);
        out
    }

    fn ownership_entries_rec(
        &self,
        node: usize,
        slot_ids: &[Option<InstanceId>],
        prefix: &mut Vec<u32>,
        out: &mut Vec<(InstanceId, Vec<u32>, f64)>,
    ) {
        if node != ROOT {
            for &(slot, stamp) in &self.nodes[node].stamps {
                if let Some(id) = slot_ids[slot as usize] {
                    out.push((id, prefix.clone(), stamp));
                }
            }
        }
        for c in self.child_indices(node) {
            prefix.extend_from_slice(&self.nodes[c].edge);
            self.ownership_entries_rec(c, slot_ids, prefix, out);
            prefix.truncate(prefix.len() - self.nodes[c].edge.len());
        }
    }

    // ------------------------------------------------------------------
    // TTL expiry (heap-driven)
    // ------------------------------------------------------------------

    /// Expire every (node, instance) pair whose last insert is older
    /// than the TTL. Pops the lazy min-heap — O(log n) per expired pair
    /// plus skipped stale entries, not a full-tree scan per victim.
    /// Returns the number of owner pairs removed (including pairs
    /// reclaimed with a dropped subtree), feeding the
    /// `sched.expired_pairs` metric.
    pub fn expire(&mut self, now: f64) -> usize {
        if self.ttl <= 0.0 {
            return 0;
        }
        let before = self.owner_pairs;
        while let Some(top) = self.heap.peek() {
            // Same staleness predicate as the reference implementation
            // (`now - last_insert > ttl`, i.e. keep while `<=`), so
            // float behavior is identical in differential tests.
            if now - top.stamp <= self.ttl {
                break;
            }
            let e = self.heap.pop().unwrap();
            let n = &self.nodes[e.node];
            if !n.valid || n.gen != e.gen {
                continue; // node was reclaimed and possibly recycled
            }
            let Ok(i) = n.stamps.binary_search_by_key(&e.slot, |s| s.0)
            else {
                continue; // ownership already cleared
            };
            if n.stamps[i].1 != e.stamp {
                continue; // re-recorded since; a fresher entry exists
            }
            let blocks = n.blocks(self.block_tokens);
            let (w, m) = word_bit(e.slot);
            let n = &mut self.nodes[e.node];
            n.stamps.remove(i);
            n.owners[w] &= !m;
            self.owner_pairs -= 1;
            self.slots[e.slot as usize].cached_blocks -= blocks;
            if self.nodes[e.node].stamps.is_empty() {
                // Last owner gone: unlink and reclaim the subtree
                // (descendants expire no later than their ancestors, so
                // any bits still set below are expired too and their
                // heap entries die with the nodes' gen bump).
                let parent = self.nodes[e.node].parent;
                self.detach_child(parent, e.node);
                self.drop_subtree(e.node);
            }
        }
        before - self.owner_pairs
    }

    fn drop_subtree(&mut self, node: usize) {
        for c in self.child_indices(node) {
            self.drop_subtree(c);
        }
        let blocks = self.nodes[node].blocks(self.block_tokens);
        let stamps = std::mem::take(&mut self.nodes[node].stamps);
        for (slot, _) in stamps {
            self.owner_pairs -= 1;
            self.slots[slot as usize].cached_blocks -= blocks;
        }
        self.release_node(node);
    }

    /// Reclaim every subtree with no owners (after membership changes).
    fn prune_ownerless(&mut self) {
        let mut stack = self.child_indices(ROOT);
        while let Some(n) = stack.pop() {
            if self.nodes[n].stamps.is_empty() {
                let parent = self.nodes[n].parent;
                self.detach_child(parent, n);
                self.drop_subtree(n);
            } else {
                stack.extend(self.child_indices(n));
            }
        }
    }

    fn entry_live(&self, e: &ExpireEntry) -> bool {
        let n = &self.nodes[e.node];
        n.valid
            && n.gen == e.gen
            && n.stamps
                .binary_search_by_key(&e.slot, |s| s.0)
                .map(|i| n.stamps[i].1 == e.stamp)
                .unwrap_or(false)
    }

    /// Bound stale-entry growth: rebuild when the heap is dominated by
    /// dead entries (shared policy with the MemPool index's LRU heap,
    /// see `util::heap`).
    fn maybe_compact_heap(&mut self) {
        if crate::util::heap::lazy_heap_needs_compact(
            self.heap.len(),
            self.owner_pairs,
        ) {
            let old = std::mem::take(&mut self.heap);
            for e in old {
                if self.entry_live(&e) {
                    self.heap.push(e);
                }
            }
        }
    }

    /// Recompute every incremental counter from scratch and compare —
    /// test/diagnostic invariant check.
    #[doc(hidden)]
    pub fn debug_check_counters(&self) {
        let mut pairs = 0usize;
        let mut blocks: crate::util::rng::DetMap<u32, usize> =
            Default::default();
        for (i, n) in self.nodes.iter().enumerate() {
            if i == ROOT || !n.valid {
                continue;
            }
            assert_eq!(
                n.stamps.len(),
                n.owners.iter().map(|w| w.count_ones() as usize).sum::<usize>(),
                "stamps/owners out of sync on node {i}"
            );
            for win in n.stamps.windows(2) {
                assert!(win[0].0 < win[1].0, "stamps unsorted on node {i}");
            }
            for &(slot, _) in &n.stamps {
                assert!(test_bit(&n.owners, slot));
                pairs += 1;
                *blocks.entry(slot).or_default() +=
                    n.blocks(self.block_tokens);
                // Prefix closure: an owned node's parent is owned (and
                // no staler).
                if n.parent != ROOT {
                    let p = &self.nodes[n.parent];
                    let j = p
                        .stamps
                        .binary_search_by_key(&slot, |s| s.0)
                        .unwrap_or_else(|_| {
                            panic!("closure violated: node {i} slot {slot}")
                        });
                    let mine = n.stamps
                        [n.stamps.binary_search_by_key(&slot, |s| s.0).unwrap()]
                    .1;
                    assert!(
                        p.stamps[j].1 >= mine,
                        "stamp monotonicity violated at node {i}"
                    );
                }
            }
        }
        assert_eq!(pairs, self.owner_pairs, "owner_pairs drifted");
        for (slot, s) in self.slots.iter().enumerate() {
            if s.live {
                assert_eq!(
                    s.cached_blocks,
                    blocks.get(&(slot as u32)).copied().unwrap_or(0),
                    "cached_blocks drifted for slot {slot}"
                );
            }
        }
        assert_eq!(
            self.by_id
                .values()
                .filter(|&&slot| self.slot_routable(slot))
                .count(),
            self.routable,
            "routable counter drifted"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const BT: usize = 4;

    fn toks(n: usize, seed: u32) -> Vec<u32> {
        (0..n as u32).map(|i| i * 3 + seed).collect()
    }

    fn match_all(
        t: &mut FusedPromptTree,
        tokens: &[u32],
    ) -> Vec<(InstanceId, usize)> {
        let mut out = vec![];
        t.match_into(tokens, &mut out);
        out
    }

    #[test]
    fn record_and_match_two_instances() {
        let mut g = FusedPromptTree::new(BT, 0.0);
        g.add_instance(InstanceId(0), InstanceKind::PrefillOnly);
        g.add_instance(InstanceId(1), InstanceKind::PrefillOnly);
        let t = toks(16, 0);
        g.record(InstanceId(1), &t, 1.0);
        assert_eq!(
            match_all(&mut g, &t),
            vec![(InstanceId(0), 0), (InstanceId(1), 16)]
        );
        g.debug_check_counters();
    }

    #[test]
    fn shared_prefix_divergence_per_instance() {
        let mut g = FusedPromptTree::new(BT, 0.0);
        g.add_instance(InstanceId(0), InstanceKind::PrefillOnly);
        g.add_instance(InstanceId(1), InstanceKind::PrefillOnly);
        // Shared 2-block prefix, divergent tails.
        let a = [1, 1, 1, 1, 2, 2, 2, 2, 3, 3, 3, 3];
        let b = [1, 1, 1, 1, 2, 2, 2, 2, 9, 9, 9, 9];
        g.record(InstanceId(0), &a, 1.0);
        g.record(InstanceId(1), &b, 2.0);
        assert_eq!(
            match_all(&mut g, &a),
            vec![(InstanceId(0), 12), (InstanceId(1), 8)]
        );
        assert_eq!(
            match_all(&mut g, &b),
            vec![(InstanceId(0), 8), (InstanceId(1), 12)]
        );
        assert_eq!(g.cached_blocks(InstanceId(0)), 3);
        assert_eq!(g.cached_blocks(InstanceId(1)), 3);
        g.debug_check_counters();
    }

    #[test]
    fn decode_only_excluded_from_route_but_match_one_works() {
        let mut g = FusedPromptTree::new(BT, 0.0);
        g.add_instance(InstanceId(0), InstanceKind::PrefillOnly);
        g.add_instance(InstanceId(1), InstanceKind::DecodeOnly);
        let t = toks(8, 0);
        g.record(InstanceId(1), &t, 1.0);
        let m = match_all(&mut g, &t);
        assert_eq!(m, vec![(InstanceId(0), 0)]);
        assert_eq!(g.match_one(InstanceId(1), &t), 8);
    }

    #[test]
    fn ttl_staleness_heap_driven() {
        let mut g = FusedPromptTree::new(BT, 10.0);
        g.add_instance(InstanceId(0), InstanceKind::Colocated);
        let t = toks(8, 5);
        g.record(InstanceId(0), &t, 0.0);
        g.expire(9.0);
        assert_eq!(g.match_one(InstanceId(0), &t), 8); // not yet stale
        g.expire(20.0);
        assert_eq!(g.match_one(InstanceId(0), &t), 0);
        assert_eq!(g.cached_blocks(InstanceId(0)), 0);
        assert_eq!(g.node_count(), 0, "ownerless subtree reclaimed");
        g.debug_check_counters();
    }

    #[test]
    fn re_record_refreshes_ttl() {
        let mut g = FusedPromptTree::new(BT, 10.0);
        g.add_instance(InstanceId(0), InstanceKind::PrefillOnly);
        let t = toks(8, 1);
        g.record(InstanceId(0), &t, 0.0);
        g.record(InstanceId(0), &t, 8.0); // refresh before expiry
        g.expire(12.0); // 0.0-stamp entries are stale, 8.0 ones live
        assert_eq!(g.match_one(InstanceId(0), &t), 8);
        g.expire(19.0);
        assert_eq!(g.match_one(InstanceId(0), &t), 0);
        g.debug_check_counters();
    }

    #[test]
    fn partial_expiry_keeps_fresher_instance() {
        let mut g = FusedPromptTree::new(BT, 10.0);
        g.add_instance(InstanceId(0), InstanceKind::PrefillOnly);
        g.add_instance(InstanceId(1), InstanceKind::PrefillOnly);
        let long = [1, 1, 1, 1, 2, 2, 2, 2];
        g.record(InstanceId(0), &long, 0.0);
        g.record(InstanceId(1), &long[..4], 5.0); // splits the node
        g.expire(12.0); // instance 0's stamps (0.0) expire everywhere
        assert_eq!(
            match_all(&mut g, &long),
            vec![(InstanceId(0), 0), (InstanceId(1), 4)]
        );
        assert_eq!(g.cached_blocks(InstanceId(0)), 0);
        assert_eq!(g.cached_blocks(InstanceId(1)), 1);
        assert_eq!(g.node_count(), 1, "expired tail reclaimed");
        g.debug_check_counters();
    }

    #[test]
    fn remove_instance_forgets_and_reclaims() {
        let mut g = FusedPromptTree::new(BT, 0.0);
        g.add_instance(InstanceId(0), InstanceKind::PrefillOnly);
        g.add_instance(InstanceId(1), InstanceKind::PrefillOnly);
        let t = toks(8, 1);
        g.record(InstanceId(0), &t, 1.0);
        g.record(InstanceId(1), &t, 1.0);
        g.remove_instance(InstanceId(0));
        assert_eq!(match_all(&mut g, &t), vec![(InstanceId(1), 8)]);
        g.remove_instance(InstanceId(1));
        assert!(match_all(&mut g, &t).is_empty());
        assert_eq!(g.node_count(), 0);
        // Slot reuse: a new instance must not inherit ghost ownership.
        g.add_instance(InstanceId(7), InstanceKind::PrefillOnly);
        assert_eq!(match_all(&mut g, &t), vec![(InstanceId(7), 0)]);
        g.debug_check_counters();
    }

    #[test]
    fn partial_blocks_rounded_down() {
        let mut g = FusedPromptTree::new(BT, 0.0);
        g.add_instance(InstanceId(0), InstanceKind::PrefillOnly);
        g.record(InstanceId(0), &toks(6, 0), 1.0);
        assert_eq!(g.match_one(InstanceId(0), &toks(6, 0)), 4);
        assert_eq!(g.cached_blocks(InstanceId(0)), 1);
    }

    #[test]
    fn more_than_64_instances_span_words() {
        let mut g = FusedPromptTree::new(BT, 0.0);
        for i in 0..70 {
            g.add_instance(InstanceId(i), InstanceKind::PrefillOnly);
        }
        let t = toks(8, 2);
        g.record(InstanceId(69), &t, 1.0);
        g.record(InstanceId(3), &t[..4], 1.0);
        let m = match_all(&mut g, &t);
        assert_eq!(m.len(), 70);
        for &(id, matched) in &m {
            let expect = match id.0 {
                69 => 8,
                3 => 4,
                _ => 0,
            };
            assert_eq!(matched, expect, "instance {id}");
        }
        g.debug_check_counters();
    }

    #[test]
    fn colliding_fingerprints_still_resolve_by_tokens() {
        let mut g = FusedPromptTree::new(BT, 0.0);
        g.set_fingerprint_mask(0);
        g.add_instance(InstanceId(0), InstanceKind::PrefillOnly);
        let a = [1u32, 1, 1, 1];
        let b = [2u32, 2, 2, 2];
        let c = [3u32, 3, 3, 3];
        g.record(InstanceId(0), &a, 1.0);
        g.record(InstanceId(0), &b, 1.0);
        g.record(InstanceId(0), &c, 1.0);
        assert_eq!(g.node_count(), 3);
        assert_eq!(g.match_one(InstanceId(0), &a), 4);
        assert_eq!(g.match_one(InstanceId(0), &b), 4);
        assert_eq!(g.match_one(InstanceId(0), &c), 4);
        assert_eq!(g.match_one(InstanceId(0), &[4, 4, 4, 4]), 0);
        g.debug_check_counters();
    }

    #[test]
    fn draining_excluded_from_route_but_still_matchable() {
        let mut g = FusedPromptTree::new(BT, 0.0);
        g.add_instance(InstanceId(0), InstanceKind::PrefillOnly);
        g.add_instance(InstanceId(1), InstanceKind::PrefillOnly);
        let t = toks(8, 0);
        g.record(InstanceId(0), &t, 1.0);
        g.set_draining(InstanceId(0), true);
        assert!(g.is_draining(InstanceId(0)));
        // Routing no longer sees instance 0 at all — not even as a
        // zero-match candidate.
        assert_eq!(match_all(&mut g, &t), vec![(InstanceId(1), 0)]);
        // But its data stays matchable for migration/donor reads.
        assert_eq!(g.match_one(InstanceId(0), &t), 8);
        assert_eq!(g.cached_blocks(InstanceId(0)), 2);
        // Un-drain restores visibility (aborted scale-down).
        g.set_draining(InstanceId(0), false);
        assert_eq!(
            match_all(&mut g, &t),
            vec![(InstanceId(0), 8), (InstanceId(1), 0)]
        );
        g.debug_check_counters();
    }

    #[test]
    fn release_prefix_keeps_proper_prefixes_and_siblings() {
        let mut g = FusedPromptTree::new(BT, 0.0);
        g.add_instance(InstanceId(0), InstanceKind::PrefillOnly);
        // Two branches sharing block A: A-B-C and A-D.
        let abc = [1, 1, 1, 1, 2, 2, 2, 2, 3, 3, 3, 3];
        let ad = [1, 1, 1, 1, 9, 9, 9, 9];
        g.record(InstanceId(0), &abc, 1.0);
        g.record(InstanceId(0), &ad, 1.0);
        assert_eq!(g.cached_blocks(InstanceId(0)), 4);
        // Release A-B: loses B and the C extension; keeps A and A-D.
        g.release_prefix(InstanceId(0), &abc[..8]);
        assert_eq!(g.match_one(InstanceId(0), &abc), 4);
        assert_eq!(g.match_one(InstanceId(0), &ad), 8);
        assert_eq!(g.cached_blocks(InstanceId(0)), 2);
        // Empty prefix clears the whole view.
        g.release_prefix(InstanceId(0), &[]);
        assert_eq!(g.cached_blocks(InstanceId(0)), 0);
        assert_eq!(g.node_count(), 0);
        g.debug_check_counters();
    }

    #[test]
    fn release_prefix_splits_inside_long_edge() {
        let mut g = FusedPromptTree::new(BT, 0.0);
        g.add_instance(InstanceId(0), InstanceKind::PrefillOnly);
        g.add_instance(InstanceId(1), InstanceKind::PrefillOnly);
        let t = toks(16, 0); // one 4-block leaf edge
        g.record(InstanceId(0), &t, 1.0);
        g.record(InstanceId(1), &t, 1.0);
        // Instance 0 releases the 2-block prefix: it keeps 1 block;
        // instance 1 is untouched.
        g.release_prefix(InstanceId(0), &t[..8]);
        assert_eq!(g.match_one(InstanceId(0), &t), 4);
        assert_eq!(g.match_one(InstanceId(1), &t), 16);
        assert_eq!(g.cached_blocks(InstanceId(0)), 1);
        assert_eq!(g.cached_blocks(InstanceId(1)), 4);
        // Releasing a prefix the instance does not fully cache: no-op.
        g.release_prefix(InstanceId(0), &t[..12]);
        assert_eq!(g.cached_blocks(InstanceId(0)), 1);
        g.debug_check_counters();
    }

    #[test]
    fn handoff_delta_repoints_ownership_atomically() {
        let mut g = FusedPromptTree::new(BT, 0.0);
        g.apply_delta(&DeltaEvent::Join {
            instance: InstanceId(0),
            kind: InstanceKind::PrefillOnly,
        });
        g.apply_delta(&DeltaEvent::Join {
            instance: InstanceId(1),
            kind: InstanceKind::PrefillOnly,
        });
        let t = toks(12, 3);
        g.apply_delta(&DeltaEvent::Record {
            instance: InstanceId(0),
            tokens: t.clone(),
            now: 1.0,
        });
        g.apply_delta(&DeltaEvent::SetDraining {
            instance: InstanceId(0),
            draining: true,
        });
        g.apply_delta(&DeltaEvent::Handoff {
            from: InstanceId(0),
            to: InstanceId(1),
            tokens: t.clone(),
            now: 2.0,
        });
        // Receiver owns the full prefix; donor retains only the proper
        // prefixes below the handed tail (honest: it still holds them).
        assert_eq!(g.match_one(InstanceId(1), &t), 12);
        assert_eq!(g.match_one(InstanceId(0), &t), 8);
        assert_eq!(match_all(&mut g, &t), vec![(InstanceId(1), 12)]);
        g.apply_delta(&DeltaEvent::Leave {
            instance: InstanceId(0),
        });
        assert_eq!(g.match_one(InstanceId(1), &t), 12);
        g.debug_check_counters();
    }

    #[test]
    fn owned_paths_enumerates_maximal_prefixes() {
        let mut g = FusedPromptTree::new(BT, 0.0);
        g.add_instance(InstanceId(0), InstanceKind::PrefillOnly);
        g.add_instance(InstanceId(1), InstanceKind::PrefillOnly);
        let abc = [1, 1, 1, 1, 2, 2, 2, 2, 3, 3, 3, 3];
        let ad = [1, 1, 1, 1, 9, 9, 9, 9];
        g.record(InstanceId(0), &abc, 1.0);
        g.record(InstanceId(0), &ad, 5.0);
        // Instance 1 extends A-D deeper: its path is maximal for *it*
        // only; instance 0's A-D path stays 2 blocks.
        let adx = [1, 1, 1, 1, 9, 9, 9, 9, 7, 7, 7, 7];
        g.record(InstanceId(1), &adx, 6.0);
        let paths = g.owned_paths(InstanceId(0));
        assert_eq!(paths.len(), 2);
        assert_eq!(paths[0].tokens, abc.to_vec());
        assert_eq!(paths[0].blocks, 3);
        assert_eq!(paths[0].last_insert, 1.0);
        assert_eq!(paths[1].tokens, ad.to_vec());
        assert_eq!(paths[1].blocks, 2);
        assert_eq!(paths[1].last_insert, 5.0);
        let p1 = g.owned_paths(InstanceId(1));
        assert_eq!(p1.len(), 1);
        assert_eq!(p1[0].tokens, adx.to_vec());
        assert!(g.owned_paths(InstanceId(9)).is_empty());
    }

    #[test]
    fn release_prefix_with_forced_collisions() {
        let mut g = FusedPromptTree::new(BT, 0.0);
        g.set_fingerprint_mask(0);
        g.add_instance(InstanceId(0), InstanceKind::PrefillOnly);
        let a = [1u32, 1, 1, 1, 5, 5, 5, 5];
        let b = [2u32, 2, 2, 2];
        let c = [3u32, 3, 3, 3];
        g.record(InstanceId(0), &a, 1.0);
        g.record(InstanceId(0), &b, 1.0);
        g.record(InstanceId(0), &c, 1.0);
        g.release_prefix(InstanceId(0), &a[..4]);
        assert_eq!(g.match_one(InstanceId(0), &a), 0);
        assert_eq!(g.match_one(InstanceId(0), &b), 4);
        assert_eq!(g.match_one(InstanceId(0), &c), 4);
        assert_eq!(g.cached_blocks(InstanceId(0)), 2);
        g.debug_check_counters();
    }

    #[test]
    fn capped_match_emits_warm_plus_cold_sample() {
        let mut g = FusedPromptTree::new(BT, 0.0);
        for i in 0..12 {
            g.add_instance(InstanceId(i), InstanceKind::PrefillOnly);
        }
        let t = toks(12, 0);
        g.record(InstanceId(3), &t, 1.0); // deep
        g.record(InstanceId(7), &t[..4], 1.0); // shallow drop-out
        // Cold rank: prefer high ids (reversed), to prove the sample
        // follows the rank, not id order.
        let mut rank =
            |id: InstanceId| -> ColdRank { (0.0, u64::MAX - id.0 as u64, 0) };
        let mut out = vec![];
        g.match_into_capped(&t, &mut out, 2, &mut rank);
        // Warm: 3 (12) and 7 (4). Cold sample: the two highest ids that
        // are cold — 11 and 10. Ascending-id emission order.
        assert_eq!(out, vec![
            (InstanceId(3), 12),
            (InstanceId(7), 4),
            (InstanceId(10), 0),
            (InstanceId(11), 0),
        ]);
        // Small fleet (cap >= routable): identical to full emission.
        let mut full = vec![];
        g.match_into(&t, &mut full);
        let mut capped = vec![];
        g.match_into_capped(&t, &mut capped, 64, &mut rank);
        assert_eq!(capped, full);
        // Draining instances stay invisible in the capped path too.
        g.set_draining(InstanceId(11), true);
        g.match_into_capped(&t, &mut out, 2, &mut rank);
        assert!(out.iter().all(|&(id, _)| id != InstanceId(11)));
        // cap 0: warm-only emission ("at most cold_cap" includes zero).
        g.match_into_capped(&t, &mut out, 0, &mut rank);
        assert_eq!(out, vec![(InstanceId(3), 12), (InstanceId(7), 4)]);
    }

    #[test]
    fn capped_match_ties_break_by_lowest_id() {
        let mut g = FusedPromptTree::new(BT, 0.0);
        for i in 0..8 {
            g.add_instance(InstanceId(i), InstanceKind::PrefillOnly);
        }
        g.record(InstanceId(0), &toks(4, 0), 1.0);
        // All-equal ranks: the sample must be the lowest cold ids, so a
        // policy that breaks ties by id sees the same winner as with
        // full emission.
        let mut rank = |_: InstanceId| -> ColdRank { (1.0, 2, 3) };
        let mut out = vec![];
        g.match_into_capped(&toks(4, 0), &mut out, 3, &mut rank);
        assert_eq!(out, vec![
            (InstanceId(0), 4),
            (InstanceId(1), 0),
            (InstanceId(2), 0),
            (InstanceId(3), 0),
        ]);
    }

    #[test]
    fn ownership_entries_roundtrip_via_record_replay() {
        let mut g = FusedPromptTree::new(BT, 10.0);
        g.add_instance(InstanceId(0), InstanceKind::PrefillOnly);
        g.add_instance(InstanceId(1), InstanceKind::PrefillOnly);
        let abc = [1, 1, 1, 1, 2, 2, 2, 2, 3, 3, 3, 3];
        let ad = [1, 1, 1, 1, 9, 9, 9, 9];
        g.record(InstanceId(0), &abc, 1.0);
        g.record(InstanceId(1), &ad, 2.0);
        // Re-record a shorter prefix later: the interior node's stamp is
        // now fresher than its descendants' — the case maximal-path
        // iteration would lose.
        g.record(InstanceId(0), &abc[..4], 5.0);
        let mut entries = g.ownership_entries();
        entries.sort_by(|a, b| {
            a.2.total_cmp(&b.2)
                .then(a.0.cmp(&b.0))
                .then(a.1.cmp(&b.1))
        });
        let mut r = FusedPromptTree::new(BT, 10.0);
        r.add_instance(InstanceId(0), InstanceKind::PrefillOnly);
        r.add_instance(InstanceId(1), InstanceKind::PrefillOnly);
        for (id, tokens, stamp) in &entries {
            r.record(*id, tokens, *stamp);
        }
        r.debug_check_counters();
        assert_eq!(r.cached_blocks(InstanceId(0)), 3);
        assert_eq!(r.cached_blocks(InstanceId(1)), 2);
        // Stamp fidelity: at now=12 the ttl-10 entries stamped 1.0/2.0
        // expire but the 5.0 re-record survives — in both trees.
        g.expire(12.0);
        r.expire(12.0);
        for t in [&abc[..], &ad[..]] {
            assert_eq!(
                g.match_one(InstanceId(0), t),
                r.match_one(InstanceId(0), t)
            );
            assert_eq!(
                g.match_one(InstanceId(1), t),
                r.match_one(InstanceId(1), t)
            );
        }
        assert_eq!(r.match_one(InstanceId(0), &abc), 4);
    }

    #[test]
    fn match_into_reuses_buffer_without_allocating() {
        let mut g = FusedPromptTree::new(BT, 0.0);
        g.add_instance(InstanceId(0), InstanceKind::PrefillOnly);
        g.record(InstanceId(0), &toks(8, 0), 1.0);
        let mut out = Vec::with_capacity(4);
        g.match_into(&toks(8, 0), &mut out);
        let cap = out.capacity();
        let ptr = out.as_ptr();
        g.match_into(&toks(8, 0), &mut out);
        assert_eq!(out.capacity(), cap);
        assert_eq!(out.as_ptr(), ptr, "buffer must be reused");
        assert_eq!(out, vec![(InstanceId(0), 8)]);
    }
}
