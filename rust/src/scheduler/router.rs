//! The global scheduler core (paper §6): tokenize → match global trees →
//! policy decision → dispatch metadata, plus the response-path tree
//! update. Transport-agnostic: the live server and the discrete-event
//! simulator both drive this object.
//!
//! Since the prefix-range sharding (ISSUE 5), `trees` is a
//! [`ShardedPromptTrees`]: S independent fused trees partitioned by the
//! prompt's first token-block fingerprint. A route walks exactly one
//! shard (a prompt's whole prefix chain shares block 0, hence its
//! shard); S = 1 is bit-identical to the unsharded path.
//!
//! Loads now live in a policy-ordered **load book** inside the
//! scheduler ([`GlobalScheduler::set_load`]) instead of a per-route
//! callback. That turns the capped-emission cold sample from "evaluate
//! the rank for every zero-match instance" (O(instances) per route)
//! into an ordered-prefix scan: O(cold_cap log instances **plus the
//! boundary tie class**, with the ordering maintained incrementally —
//! an unchanged load is O(1) to re-assert. The tie class is the set of
//! instances sharing the cold_cap-th key exactly; it must be collected
//! whole because the per-route session tie-break can pick any of them,
//! so a fully-idle fleet (all keys equal) honestly degenerates to the
//! old O(instances) scan — no worse than before, and the bound
//! tightens as soon as loads differentiate.

use std::collections::BTreeSet;

use crate::mempool::InstanceId;
use crate::obs::{Counter, Histo, Labels, Registry};
use crate::scheduler::cost_model::OperatorCostModel;
use crate::scheduler::fused_tree::{cold_rank_cmp, ColdRank};
use crate::scheduler::policy::{decide, Candidate, Decision, PolicyKind};
use crate::scheduler::prompt_tree::InstanceKind;
use crate::scheduler::shard::ShardedPromptTrees;
use crate::util::rng::{DetMap, DetSet};

/// Per-instance load the caller keeps updated (queued prompt tokens).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct InstanceLoad {
    pub queued_tokens: usize,
    pub queued_cached_ratio: f64,
    pub running: usize,
    /// Pool occupancy in [0, 1]; near-full pools churn and Eq. 1
    /// discounts their matched length (`cost_model::pressure_discount`).
    pub capacity_pressure: f64,
}

/// What the GS tells the chosen instance (and the caller) to do.
#[derive(Clone, Debug)]
pub struct RouteOutcome {
    pub decision: Decision,
    /// Expected prefill seconds on the chosen instance (cost model).
    pub expected_prefill_s: f64,
    /// Eq. 2 verdict when a donor exists: fetch the extra prefix?
    pub fetch_from_donor: bool,
}

/// Totally ordered f64 for the load book's BTreeSet key.
#[derive(Clone, Copy, Debug, PartialEq)]
struct OrdF64(f64);

impl Eq for OrdF64 {}

impl PartialOrd for OrdF64 {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for OrdF64 {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

/// The load-dependent prefix of the active policy's cold ordering —
/// everything except the per-route session tie-break, so it can be
/// maintained across routes. LeastLoad: `(queued, 0)` (its true
/// tie-break is the instance id, which the BTreeSet key appends).
/// Cost policies: `(exec(queued, cached_ratio), queued)`.
type BookKey = (OrdF64, u64);

/// Policy-ordered load registry: `order` iterates instances from the
/// cold-best rank upward, so the capped route takes an ordered prefix
/// instead of ranking the whole fleet.
#[derive(Debug, Default)]
struct LoadBook {
    loads: DetMap<InstanceId, (InstanceLoad, BookKey)>,
    order: BTreeSet<(BookKey, InstanceId)>,
}

impl LoadBook {
    /// O(log n) when the rank key changed, O(1) otherwise.
    fn set(&mut self, id: InstanceId, load: InstanceLoad, key: BookKey) {
        match self.loads.entry(id) {
            std::collections::hash_map::Entry::Occupied(mut e) => {
                let (l, k) = e.get_mut();
                if *k != key {
                    self.order.remove(&(*k, id));
                    self.order.insert((key, id));
                    *k = key;
                }
                *l = load;
            }
            std::collections::hash_map::Entry::Vacant(e) => {
                e.insert((load, key));
                self.order.insert((key, id));
            }
        }
    }

    fn remove(&mut self, id: InstanceId) {
        if let Some((_, k)) = self.loads.remove(&id) {
            self.order.remove(&(k, id));
        }
    }

    fn get(&self, id: InstanceId) -> InstanceLoad {
        self.loads.get(&id).map(|&(l, _)| l).unwrap_or_default()
    }
}

pub struct GlobalScheduler {
    pub trees: ShardedPromptTrees,
    pub policy: PolicyKind,
    pub cost: OperatorCostModel,
    /// Fabric characteristics for Eq. 2.
    pub bytes_per_token: usize,
    pub bandwidth_bytes_per_s: f64,
    pub per_call_s: f64,
    pub calls_per_token_block: usize,
    pub block_tokens: usize,
    pub transfer_decision_enabled: bool,
    /// Capped-emission knob: on fleets larger than this, routing emits
    /// only positive-match instances plus this many best-ranked cold
    /// ones instead of one pair per prefill instance — removing the
    /// O(instances) candidate scan at ~1k instances. The cold sample is
    /// drawn from the load book's policy ordering (exact boundary ties
    /// resolved with the session tie-break), so decisions are
    /// unchanged; the session-id policy (whose pick depends on the
    /// candidate *count*) always gets full emission. 0 disables.
    pub cold_sample: usize,
    /// Prefix-range shards currently degraded (ISSUE 6): their primary
    /// tree is suspected crashed and awaiting promotion, so prompts
    /// hashing into them route via the load book alone (no tree walk)
    /// instead of stalling. Cleared when the promoted snapshot lands.
    degraded_shards: DetSet<usize>,
    /// Policy-ordered per-instance loads (see [`Self::set_load`]).
    book: LoadBook,
    /// `trees.membership_gen()` the book was last synced against.
    book_gen: Option<u64>,
    /// Reusable route-path scratch: matched prefixes from the fused
    /// tree, the candidate list handed to the policy, and the cold
    /// sample. Steady-state routing performs no allocation.
    match_buf: Vec<(InstanceId, usize)>,
    cand_buf: Vec<Candidate>,
    cold_buf: Vec<(ColdRank, InstanceId)>,
    cold_sel: Vec<InstanceId>,
    /// Metric handles, attached once via [`Self::attach_obs`] (ISSUE
    /// 8). `None` = uninstrumented: zero route-path overhead.
    obs: Option<SchedObs>,
    /// Wall-clock source for the `route_us` digest, injected by live
    /// callers ([`Self::set_route_timer`], normally
    /// `util::clock::monotonic_secs`). `None` — the default, and what
    /// the simulator keeps — skips the latency sample entirely, so the
    /// scheduler core itself never reads a wall clock (archlint R1).
    route_timer: Option<fn() -> f64>,
}

/// Route-path metric handles. All writes are relaxed atomics on
/// pre-registered handles — no registry lookup per route.
struct SchedObs {
    routes: Counter,
    degraded_routes: Counter,
    expired_pairs: Counter,
    matched_tokens: Histo,
    queued_tokens: Histo,
    /// Capacity pressure in milli-units ([0, 1] × 1000), from the
    /// load book's `set_load` feed.
    pressure_milli: Histo,
    /// Wall-clock µs spent inside [`GlobalScheduler::route`] (ISSUE 9
    /// timeline feed). Wall time never reaches a decision or a
    /// virtual-clock timestamp — record-only.
    route_us: Histo,
    /// Eq. 1's predicted prefill seconds at route, µs-scaled — paired
    /// with `attrib.cost_err_pm` at retire for calibration.
    predicted_prefill_us: Histo,
}

impl GlobalScheduler {
    pub fn new(
        policy: PolicyKind,
        cost: OperatorCostModel,
        block_tokens: usize,
        ttl: f64,
    ) -> Self {
        Self::with_shards(policy, cost, block_tokens, ttl, 1)
    }

    /// Scheduler over `shards` prefix-range shards (ISSUE 5). `shards
    /// = 1` is decision- and bit-identical to the unsharded scheduler.
    pub fn with_shards(
        policy: PolicyKind,
        cost: OperatorCostModel,
        block_tokens: usize,
        ttl: f64,
        shards: usize,
    ) -> Self {
        GlobalScheduler {
            trees: ShardedPromptTrees::with_shards(block_tokens, ttl,
                                                   shards),
            policy,
            cost,
            bytes_per_token: 0,
            bandwidth_bytes_per_s: 40e9,
            per_call_s: 15e-6,
            calls_per_token_block: 1,
            block_tokens,
            transfer_decision_enabled: true,
            cold_sample: 32,
            degraded_shards: DetSet::default(),
            book: LoadBook::default(),
            book_gen: None,
            match_buf: vec![],
            cand_buf: vec![],
            cold_buf: vec![],
            cold_sel: vec![],
            obs: None,
            route_timer: None,
        }
    }

    /// Register this scheduler's route-path metrics into `reg`,
    /// labeled by data-plane shard when it serves one. Handles are
    /// resolved once here; the route path then touches only relaxed
    /// atomics (and nothing at all when the registry is disabled).
    pub fn attach_obs(&mut self, reg: &Registry, shard: Option<u32>) {
        let l = match shard {
            Some(s) => Labels::shard(s),
            None => Labels::none(),
        };
        self.obs = Some(SchedObs {
            routes: reg.counter("sched.routes", l),
            degraded_routes: reg.counter("sched.degraded_routes", l),
            expired_pairs: reg.counter("sched.expired_pairs", l),
            matched_tokens: reg.histogram("sched.matched_tokens", l),
            queued_tokens: reg.histogram("sched.queued_tokens", l),
            pressure_milli: reg.histogram("sched.pressure_milli", l),
            route_us: reg.histogram("sched.route_us", l),
            predicted_prefill_us: reg
                .histogram("sched.predicted_prefill_us", l),
        });
    }

    /// Install the wall-clock source used for the `route_us` latency
    /// digest. Live servers pass `util::clock::monotonic_secs` by
    /// name; the simulator leaves it unset (deterministic replay must
    /// not observe real time).
    pub fn set_route_timer(&mut self, timer: fn() -> f64) {
        self.route_timer = Some(timer);
    }

    pub fn add_instance(&mut self, id: InstanceId, kind: InstanceKind) {
        self.trees.add_instance(id, kind);
    }

    /// Mark one prefix-range shard degraded (or healed). While
    /// degraded, prompts hashing into it skip the tree walk and place
    /// by load alone — graceful degradation instead of a stall while
    /// the shard's promotion completes.
    pub fn set_shard_degraded(&mut self, shard: usize, degraded: bool) {
        if degraded {
            self.degraded_shards.insert(shard);
        } else {
            self.degraded_shards.remove(&shard);
        }
    }

    pub fn is_shard_degraded(&self, shard: usize) -> bool {
        self.degraded_shards.contains(&shard)
    }

    /// The load book key: the load-dependent prefix of the active
    /// policy's cold ordering (the session tie-break is per-route).
    fn rank_key(&self, l: &InstanceLoad) -> BookKey {
        match self.policy {
            PolicyKind::LeastLoad => (OrdF64(l.queued_tokens as f64), 0),
            _ => (
                OrdF64(
                    self.cost
                        .exec(l.queued_tokens, l.queued_cached_ratio),
                ),
                l.queued_tokens as u64,
            ),
        }
    }

    /// Update one instance's load. Instances never set default to idle;
    /// an unchanged load costs O(1), a changed one O(log instances).
    /// (The key is computed with the scheduler's cost model — mutate
    /// `cost` only before routing begins.)
    pub fn set_load(&mut self, id: InstanceId, load: InstanceLoad) {
        // Only prefill-capable instances enter the book: decode-only
        // ones can never be routing candidates, and keeping them out
        // keeps the ordered cold scan from stepping over their
        // permanently-idle entries (disaggregated fleets are
        // decode-heavy). Draining is per-route state and stays handled
        // by `is_route_candidate` at scan time.
        if !self
            .trees
            .kind_of(id)
            .is_some_and(|k| k.runs_prefill())
        {
            return;
        }
        if let Some(obs) = &self.obs {
            obs.queued_tokens.observe(load.queued_tokens as u64);
            obs.pressure_milli
                .observe((load.capacity_pressure.clamp(0.0, 1.0) * 1e3) as u64);
        }
        let key = self.rank_key(&load);
        self.book.set(id, load, key);
    }

    /// Resync the book's id set after membership changes (cheap no-op
    /// otherwise). Loads of surviving instances are preserved; new
    /// instances start idle.
    fn sync_book(&mut self) {
        let gen = self.trees.membership_gen();
        if self.book_gen == Some(gen) {
            return;
        }
        self.book_gen = Some(gen);
        let known: DetSet<InstanceId> = self
            .trees
            .instances()
            .filter(|&(_, kind)| kind.runs_prefill())
            .map(|(id, _)| id)
            .collect();
        let stale: Vec<InstanceId> = self
            .book
            .loads
            .keys()
            .filter(|id| !known.contains(id))
            .copied()
            .collect();
        for id in stale {
            self.book.remove(id);
        }
        let default_key = self.rank_key(&InstanceLoad::default());
        for id in known {
            if !self.book.loads.contains_key(&id) {
                self.book.set(id, InstanceLoad::default(), default_key);
            }
        }
    }

    /// Route one request among prefill-capable instances, using the
    /// loads last pushed via [`Self::set_load`] (instances never set
    /// are treated as idle).
    pub fn route(
        &mut self,
        prompt: &[u32],
        session_id: u64,
        now: f64,
    ) -> anyhow::Result<RouteOutcome> {
        // Wall-clock sample for the route_us digest — taken only when
        // both instrumented and given a timer, so the bare path (and
        // the simulator, always) pays nothing and reads no clock.
        let t0 = match (&self.obs, self.route_timer) {
            (Some(_), Some(timer)) => Some((timer, timer())),
            _ => None,
        };
        // Heap-driven TTL housekeeping rides the routing path: an O(1)
        // peek per shard when nothing has expired, O(log n) per stale
        // entry.
        let expired = self.trees.expire(now);
        self.sync_book();
        // One walk of the prompt's shard yields the matched prefix for
        // the whole fleet; all buffers are reused across routes (no
        // allocation). Large fleets get capped emission: warm instances
        // plus a cold sample drawn as an ordered prefix of the load
        // book — the book's key is the policy's exact cold ordering up
        // to the per-route session tie-break, which is resolved over
        // the boundary tie class only, so the decision cannot change.
        let Self {
            trees,
            match_buf,
            cold_buf,
            cold_sel,
            book,
            policy,
            cold_sample,
            degraded_shards,
            ..
        } = self;
        let degraded = !degraded_shards.is_empty()
            && degraded_shards.contains(
                &trees.map().shard_of_tokens(prompt).unwrap_or(0),
            );
        let capped = *cold_sample > 0
            && *policy != PolicyKind::SessionId
            && trees.instance_count() > *cold_sample;
        if degraded {
            // Fallback (ISSUE 6): the prompt's shard is blacked out —
            // its tree state is gone until the promoted snapshot
            // lands. Rather than stall (or trust a just-wiped tree),
            // emit every routable prefill instance as a zero-match
            // candidate straight from the load book; the policy's cold
            // ordering places by load. The response path keeps
            // appending Record deltas to the shard's log throughout,
            // so the restored tree still learns what was cached during
            // the blackout.
            match_buf.clear();
            for &(_, id) in book.order.iter() {
                if trees.is_route_candidate(id) {
                    match_buf.push((id, 0));
                }
            }
        } else if capped && trees.routable_count() > *cold_sample {
            trees.walk(prompt);
            cold_buf.clear();
            let mut boundary: Option<BookKey> = None;
            for &(key, id) in book.order.iter() {
                if let Some(b) = boundary {
                    if key > b {
                        break;
                    }
                }
                if !trees.is_route_candidate(id) || trees.walked_len(id) > 0
                {
                    continue;
                }
                // The full cold rank, mirroring the policy's ordering
                // over zero-match candidates (computed only for the
                // ordered prefix, not the fleet).
                let rank: ColdRank = match policy {
                    PolicyKind::LeastLoad => (key.0 .0, id.0 as u64, 0),
                    _ => {
                        let mut s = session_id ^ ((id.0 as u64) << 32);
                        (
                            key.0 .0,
                            key.1,
                            crate::util::rng::splitmix64(&mut s),
                        )
                    }
                };
                cold_buf.push((rank, id));
                if boundary.is_none() && cold_buf.len() == *cold_sample {
                    // Keep collecting through EXACT key ties: any of
                    // them could win the session tie-break.
                    boundary = Some(key);
                }
            }
            if cold_buf.len() > *cold_sample {
                cold_buf
                    .select_nth_unstable_by(*cold_sample - 1, cold_rank_cmp);
                cold_buf.truncate(*cold_sample);
            }
            cold_sel.clear();
            cold_sel.extend(cold_buf.iter().map(|&(_, id)| id));
            cold_sel.sort_unstable();
            trees.emit_walked(match_buf, cold_sel);
        } else {
            trees.match_into(prompt, match_buf);
        }
        anyhow::ensure!(
            !self.match_buf.is_empty(),
            "no prefill-capable instances registered"
        );
        self.cand_buf.clear();
        for &(id, matched) in &self.match_buf {
            let l = self.book.get(id);
            self.cand_buf.push(Candidate {
                instance: id,
                queued_tokens: l.queued_tokens,
                queued_cached_ratio: l.queued_cached_ratio,
                matched_tokens: matched,
                pressure: l.capacity_pressure,
            });
        }
        let cost = &self.cost;
        let decision = decide(
            self.policy,
            &self.cand_buf,
            prompt.len(),
            session_id,
            |x, y| cost.exec(x, y),
        );
        let x = prompt.len();
        let y_here = decision.matched_tokens as f64 / x.max(1) as f64;
        let expected_prefill_s = self.cost.exec(x, y_here);
        let fetch_from_donor = match decision.donor {
            Some((_, donor_tokens)) if self.transfer_decision_enabled => {
                let y_donor = donor_tokens as f64 / x.max(1) as f64;
                let extra_blocks = (donor_tokens
                    .saturating_sub(decision.matched_tokens))
                    / self.block_tokens.max(1);
                self.cost.should_transfer(
                    x,
                    y_here,
                    y_donor,
                    self.bytes_per_token,
                    self.bandwidth_bytes_per_s,
                    self.per_call_s,
                    extra_blocks * self.calls_per_token_block,
                )
            }
            _ => false,
        };
        if let Some(obs) = &self.obs {
            obs.routes.inc(1);
            if degraded {
                obs.degraded_routes.inc(1);
            }
            if expired > 0 {
                obs.expired_pairs.inc(expired as u64);
            }
            obs.matched_tokens.observe(decision.matched_tokens as u64);
            obs.predicted_prefill_us.observe_secs(expected_prefill_s);
            if let Some((timer, t0)) = t0 {
                obs.route_us.observe_secs((timer() - t0).max(0.0));
            }
        }
        Ok(RouteOutcome {
            decision,
            expected_prefill_s,
            fetch_from_donor,
        })
    }

    /// Response path (paper Fig 6 right): the instance now caches the
    /// prompt + generated tokens.
    pub fn record_cached(&mut self, instance: InstanceId, tokens: &[u32],
                         now: f64) {
        self.trees.record(instance, tokens, now);
    }

    /// Returns owner pairs expired this pass (also fed to the
    /// `sched.expired_pairs` counter when instrumented).
    pub fn expire(&mut self, now: f64) -> usize {
        let expired = self.trees.expire(now);
        if let Some(obs) = &self.obs {
            if expired > 0 {
                obs.expired_pairs.inc(expired as u64);
            }
        }
        expired
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gs(policy: PolicyKind) -> GlobalScheduler {
        let mut g = GlobalScheduler::new(
            policy,
            OperatorCostModel::paper_13b(),
            16,
            0.0,
        );
        g.bytes_per_token = 2 * 4 * 8 * 32 * 4;
        g.add_instance(InstanceId(0), InstanceKind::PrefillOnly);
        g.add_instance(InstanceId(1), InstanceKind::PrefillOnly);
        g.add_instance(InstanceId(2), InstanceKind::DecodeOnly);
        g
    }

    fn toks(n: usize, seed: u32) -> Vec<u32> {
        (0..n as u32).map(|i| i.wrapping_mul(31).wrapping_add(seed)).collect()
    }

    #[test]
    fn routes_to_cache_holder() {
        let mut g = gs(PolicyKind::PromptTree);
        let t = toks(256, 0);
        g.record_cached(InstanceId(1), &t, 1.0);
        let out = g.route(&t, 9, 2.0).unwrap();
        assert_eq!(out.decision.instance, InstanceId(1));
        assert_eq!(out.decision.matched_tokens, 256);
        assert!(!out.fetch_from_donor);
    }

    #[test]
    fn decode_only_never_chosen() {
        let mut g = gs(PolicyKind::LeastLoad);
        for s in 0..20 {
            let out = g.route(&toks(64, s), s as u64, 1.0).unwrap();
            assert_ne!(out.decision.instance, InstanceId(2));
        }
    }

    #[test]
    fn donor_transfer_engages_for_big_gap() {
        let mut g = gs(PolicyKind::PromptTree);
        g.bandwidth_bytes_per_s = 200e9;
        let t = toks(4096, 1);
        // Instance 0 has nearly everything cached but is overloaded, so
        // Eq. 1 picks instance 1; Eq. 2 should then fetch from 0.
        g.record_cached(InstanceId(0), &t, 1.0);
        g.set_load(InstanceId(0), InstanceLoad {
            queued_tokens: 1_000_000,
            ..Default::default()
        });
        let out = g.route(&t, 3, 2.0).unwrap();
        assert_eq!(out.decision.instance, InstanceId(1));
        let (donor, donor_tokens) = out.decision.donor.unwrap();
        assert_eq!(donor, InstanceId(0));
        assert_eq!(donor_tokens, 4096);
        assert!(out.fetch_from_donor);
    }

    #[test]
    fn expected_prefill_reflects_cache() {
        let mut g = gs(PolicyKind::PromptTree);
        let t = toks(1024, 7);
        let cold = g.route(&t, 0, 1.0).unwrap().expected_prefill_s;
        g.record_cached(InstanceId(0), &t, 1.5);
        let warm = g.route(&t, 0, 2.0).unwrap().expected_prefill_s;
        assert!(warm < cold, "warm={warm} cold={cold}");
    }

    #[test]
    fn draining_instance_never_routed() {
        let mut g = gs(PolicyKind::PromptTree);
        let t = toks(256, 0);
        // Instance 1 holds the cache but is draining: routing must go
        // elsewhere even though the match is perfect.
        g.record_cached(InstanceId(1), &t, 1.0);
        g.trees.set_draining(InstanceId(1), true);
        for s in 0..10 {
            let out = g.route(&t, s, 2.0).unwrap();
            assert_ne!(out.decision.instance, InstanceId(1));
            // Nor may it appear as an Eq. 2 donor — migration, not
            // ad-hoc donor fetch, moves a draining instance's KV.
            assert!(out.decision.donor.is_none());
        }
        // Its view is still there for the migration planner.
        assert_eq!(g.trees.match_one(InstanceId(1), &t), 256);
    }

    #[test]
    fn capacity_pressure_steers_routing() {
        let mut g = gs(PolicyKind::PromptTree);
        let t = toks(1024, 2);
        // Both instances cache the prompt; 0 churns at full pressure.
        g.record_cached(InstanceId(0), &t, 1.0);
        g.record_cached(InstanceId(1), &t, 1.0);
        g.set_load(InstanceId(0), InstanceLoad {
            capacity_pressure: 1.0,
            ..Default::default()
        });
        let out = g.route(&t, 0, 2.0).unwrap();
        assert_eq!(out.decision.instance, InstanceId(1));
    }

    #[test]
    fn capped_emission_preserves_decisions_at_scale() {
        // 80 instances (> the 32-instance cap trigger), varied loads,
        // a few cache holders: capped (load-book ordered prefix) and
        // full emission must route identically for the load-monotone
        // policies.
        for policy in [PolicyKind::PromptTree, PolicyKind::LeastLoad] {
            let mk = |cold_sample: usize| {
                let mut g = GlobalScheduler::new(
                    policy,
                    OperatorCostModel::paper_13b(),
                    16,
                    0.0,
                );
                g.cold_sample = cold_sample;
                for i in 0..80 {
                    g.add_instance(InstanceId(i), InstanceKind::PrefillOnly);
                    g.set_load(InstanceId(i), InstanceLoad {
                        queued_tokens: ((i as u64 * 2654435761) % 4096)
                            as usize,
                        ..Default::default()
                    });
                }
                g
            };
            let mut capped = mk(8);
            let mut full = mk(0);
            for s in 0..30u64 {
                let t = toks(256, (s % 5) as u32);
                if s < 3 {
                    capped.record_cached(InstanceId(s as u32 * 7), &t, 0.5);
                    full.record_cached(InstanceId(s as u32 * 7), &t, 0.5);
                }
                let a = capped.route(&t, s, 1.0).unwrap();
                let b = full.route(&t, s, 1.0).unwrap();
                assert_eq!(a.decision, b.decision, "policy {policy:?} s={s}");
            }
        }
    }

    #[test]
    fn capped_emission_survives_load_and_membership_churn() {
        // The load book is incremental: mutate loads between routes,
        // drain/undrain, and join instances mid-stream — the ordered
        // prefix must keep matching full emission decision-for-decision.
        let mut capped = GlobalScheduler::new(
            PolicyKind::PromptTree,
            OperatorCostModel::paper_13b(),
            16,
            0.0,
        );
        capped.cold_sample = 6;
        let mut full = GlobalScheduler::new(
            PolicyKind::PromptTree,
            OperatorCostModel::paper_13b(),
            16,
            0.0,
        );
        full.cold_sample = 0;
        for i in 0..40 {
            capped.add_instance(InstanceId(i), InstanceKind::PrefillOnly);
            full.add_instance(InstanceId(i), InstanceKind::PrefillOnly);
        }
        for s in 0..60u64 {
            // Churn a couple of loads per route (ties included: the
            // same queued value lands on several instances).
            for k in 0..3u64 {
                let id = InstanceId(((s * 7 + k * 13) % 40) as u32);
                let load = InstanceLoad {
                    queued_tokens: ((s + k) % 5) as usize * 128,
                    ..Default::default()
                };
                capped.set_load(id, load);
                full.set_load(id, load);
            }
            if s == 20 {
                capped.trees.set_draining(InstanceId(3), true);
                full.trees.set_draining(InstanceId(3), true);
            }
            if s == 40 {
                capped.add_instance(InstanceId(99),
                                    InstanceKind::PrefillOnly);
                full.add_instance(InstanceId(99), InstanceKind::PrefillOnly);
            }
            let t = toks(128, (s % 4) as u32);
            let a = capped.route(&t, s, 1.0).unwrap();
            let b = full.route(&t, s, 1.0).unwrap();
            assert_eq!(a.decision, b.decision, "s={s}");
        }
    }

    #[test]
    fn sharded_routes_match_unsharded() {
        // ISSUE 5 acceptance at the router level: S ∈ {1, 2, 4}
        // schedulers make byte-identical decisions to the S=1 path
        // across records, loads, and repeat routes.
        for shards in [1usize, 2, 4] {
            let mk = |s: usize| {
                let mut g = GlobalScheduler::with_shards(
                    PolicyKind::PromptTree,
                    OperatorCostModel::paper_13b(),
                    16,
                    0.0,
                    s,
                );
                for i in 0..12 {
                    g.add_instance(InstanceId(i), InstanceKind::PrefillOnly);
                    g.set_load(InstanceId(i), InstanceLoad {
                        queued_tokens: (i as usize * 97) % 1024,
                        ..Default::default()
                    });
                }
                g
            };
            let mut shd = mk(shards);
            let mut flat = mk(1);
            for s in 0..40u64 {
                let t = toks(256, (s % 7) as u32);
                let a = shd.route(&t, s, 1.0).unwrap();
                let b = flat.route(&t, s, 1.0).unwrap();
                assert_eq!(a.decision, b.decision, "S={shards} s={s}");
                shd.record_cached(a.decision.instance, &t, 1.0);
                flat.record_cached(b.decision.instance, &t, 1.0);
            }
        }
    }

    #[test]
    fn degraded_shard_serves_loadbook_only_and_rewarms() {
        let mut g = gs(PolicyKind::PromptTree);
        let t = toks(256, 0);
        g.record_cached(InstanceId(1), &t, 1.0);
        // Healthy: the cache holder wins.
        let out = g.route(&t, 9, 2.0).unwrap();
        assert_eq!(out.decision.instance, InstanceId(1));
        assert_eq!(out.decision.matched_tokens, 256);
        // Blackout: the prompt's shard (S=1 → shard 0) degrades. The
        // route must still succeed — zero-match placement by load —
        // and must not consult the (suspect) tree.
        g.set_shard_degraded(0, true);
        assert!(g.is_shard_degraded(0));
        g.set_load(InstanceId(0), InstanceLoad {
            queued_tokens: 10_000,
            ..Default::default()
        });
        let out = g.route(&t, 9, 3.0).unwrap();
        assert_eq!(out.decision.matched_tokens, 0, "no tree walk");
        assert_eq!(
            out.decision.instance,
            InstanceId(1),
            "load-only placement picks the idle instance"
        );
        assert!(out.decision.donor.is_none());
        // Re-warm: tree-guided placement resumes, cache intact.
        g.set_shard_degraded(0, false);
        let out = g.route(&t, 9, 4.0).unwrap();
        assert_eq!(out.decision.instance, InstanceId(1));
        assert_eq!(out.decision.matched_tokens, 256);
    }

    #[test]
    fn degraded_other_shard_leaves_routing_untouched() {
        // S=4: degrade a shard the prompt does NOT hash into — the
        // tree-guided decision must be unchanged.
        let mut g = GlobalScheduler::with_shards(
            PolicyKind::PromptTree,
            OperatorCostModel::paper_13b(),
            16,
            0.0,
            4,
        );
        for i in 0..4 {
            g.add_instance(InstanceId(i), InstanceKind::PrefillOnly);
        }
        let t = toks(256, 3);
        let home = g.trees.map().shard_of_tokens(&t).unwrap();
        g.record_cached(InstanceId(2), &t, 1.0);
        g.set_shard_degraded((home + 1) % 4, true);
        let out = g.route(&t, 5, 2.0).unwrap();
        assert_eq!(out.decision.instance, InstanceId(2));
        assert_eq!(out.decision.matched_tokens, 256);
    }

    #[test]
    fn transfer_decision_can_be_disabled() {
        let mut g = gs(PolicyKind::PromptTree);
        g.transfer_decision_enabled = false;
        g.bandwidth_bytes_per_s = 1e15;
        let t = toks(4096, 1);
        g.record_cached(InstanceId(0), &t, 1.0);
        g.set_load(InstanceId(0), InstanceLoad {
            queued_tokens: 1_000_000,
            ..Default::default()
        });
        let out = g.route(&t, 3, 2.0).unwrap();
        assert!(!out.fetch_from_donor);
    }
}
