//! The global scheduler core (paper §6): tokenize → match global trees →
//! policy decision → dispatch metadata, plus the response-path tree
//! update. Transport-agnostic: the live server and the discrete-event
//! simulator both drive this object.

use crate::mempool::InstanceId;
use crate::scheduler::cost_model::OperatorCostModel;
use crate::scheduler::policy::{decide, Candidate, Decision, PolicyKind};
use crate::scheduler::prompt_tree::{GlobalPromptTrees, InstanceKind};

/// Per-instance load the caller keeps updated (queued prompt tokens).
#[derive(Clone, Copy, Debug, Default)]
pub struct InstanceLoad {
    pub queued_tokens: usize,
    pub queued_cached_ratio: f64,
    pub running: usize,
    /// Pool occupancy in [0, 1]; near-full pools churn and Eq. 1
    /// discounts their matched length (`cost_model::pressure_discount`).
    pub capacity_pressure: f64,
}

/// What the GS tells the chosen instance (and the caller) to do.
#[derive(Clone, Debug)]
pub struct RouteOutcome {
    pub decision: Decision,
    /// Expected prefill seconds on the chosen instance (cost model).
    pub expected_prefill_s: f64,
    /// Eq. 2 verdict when a donor exists: fetch the extra prefix?
    pub fetch_from_donor: bool,
}

pub struct GlobalScheduler {
    pub trees: GlobalPromptTrees,
    pub policy: PolicyKind,
    pub cost: OperatorCostModel,
    /// Fabric characteristics for Eq. 2.
    pub bytes_per_token: usize,
    pub bandwidth_bytes_per_s: f64,
    pub per_call_s: f64,
    pub calls_per_token_block: usize,
    pub block_tokens: usize,
    pub transfer_decision_enabled: bool,
    /// Capped-emission knob: on fleets larger than this, the fused tree
    /// emits only positive-match instances plus this many best-ranked
    /// cold ones (`FusedPromptTree::match_into_capped`) instead of one
    /// pair per prefill instance — removing the O(instances) candidate
    /// scan at ~1k instances. The cold ranking mirrors the active
    /// policy's exact ordering over zero-match candidates, so decisions
    /// are unchanged; the session-id policy (whose pick depends on the
    /// candidate *count*) always gets full emission. 0 disables.
    pub cold_sample: usize,
    /// Reusable route-path scratch: matched prefixes from the fused
    /// tree and the candidate list handed to the policy. Steady-state
    /// routing performs no allocation.
    match_buf: Vec<(InstanceId, usize)>,
    cand_buf: Vec<Candidate>,
}

impl GlobalScheduler {
    pub fn new(
        policy: PolicyKind,
        cost: OperatorCostModel,
        block_tokens: usize,
        ttl: f64,
    ) -> Self {
        GlobalScheduler {
            trees: GlobalPromptTrees::new(block_tokens, ttl),
            policy,
            cost,
            bytes_per_token: 0,
            bandwidth_bytes_per_s: 40e9,
            per_call_s: 15e-6,
            calls_per_token_block: 1,
            block_tokens,
            transfer_decision_enabled: true,
            cold_sample: 32,
            match_buf: vec![],
            cand_buf: vec![],
        }
    }

    pub fn add_instance(&mut self, id: InstanceId, kind: InstanceKind) {
        self.trees.add_instance(id, kind);
    }

    /// Route one request among prefill-capable instances.
    ///
    /// `loads` must supply an entry for every candidate returned by the
    /// trees (missing entries are treated as idle).
    pub fn route(
        &mut self,
        prompt: &[u32],
        session_id: u64,
        loads: &dyn Fn(InstanceId) -> InstanceLoad,
        now: f64,
    ) -> anyhow::Result<RouteOutcome> {
        // Heap-driven TTL housekeeping rides the routing path: an O(1)
        // peek when nothing has expired, O(log n) per stale entry.
        self.trees.expire(now);
        // One fused-tree walk yields the matched prefix for the whole
        // fleet; both buffers are reused across routes (no allocation).
        // Large fleets get capped emission: warm instances plus a cold
        // sample ranked exactly as the policy would rank zero-match
        // candidates — cost (monotone in queue), then queue, then the
        // policy's own tie-break — so the decision cannot change.
        let Self {
            trees,
            match_buf,
            cost,
            policy,
            cold_sample,
            ..
        } = self;
        if *cold_sample > 0
            && *policy != PolicyKind::SessionId
            && trees.instance_count() > *cold_sample
        {
            let mut rank = |id: InstanceId| {
                let l = loads(id);
                match policy {
                    PolicyKind::LeastLoad => {
                        (l.queued_tokens as f64, id.0 as u64, 0)
                    }
                    _ => {
                        let mut s = session_id ^ ((id.0 as u64) << 32);
                        (
                            cost.exec(
                                l.queued_tokens,
                                l.queued_cached_ratio,
                            ),
                            l.queued_tokens as u64,
                            crate::util::rng::splitmix64(&mut s),
                        )
                    }
                }
            };
            trees.match_into_capped(prompt, match_buf, *cold_sample,
                                    &mut rank);
        } else {
            trees.match_into(prompt, match_buf);
        }
        anyhow::ensure!(
            !self.match_buf.is_empty(),
            "no prefill-capable instances registered"
        );
        self.cand_buf.clear();
        for &(id, matched) in &self.match_buf {
            let l = loads(id);
            self.cand_buf.push(Candidate {
                instance: id,
                queued_tokens: l.queued_tokens,
                queued_cached_ratio: l.queued_cached_ratio,
                matched_tokens: matched,
                pressure: l.capacity_pressure,
            });
        }
        let cost = &self.cost;
        let decision = decide(
            self.policy,
            &self.cand_buf,
            prompt.len(),
            session_id,
            |x, y| cost.exec(x, y),
        );
        let x = prompt.len();
        let y_here = decision.matched_tokens as f64 / x.max(1) as f64;
        let expected_prefill_s = self.cost.exec(x, y_here);
        let fetch_from_donor = match decision.donor {
            Some((_, donor_tokens)) if self.transfer_decision_enabled => {
                let y_donor = donor_tokens as f64 / x.max(1) as f64;
                let extra_blocks = (donor_tokens
                    .saturating_sub(decision.matched_tokens))
                    / self.block_tokens.max(1);
                self.cost.should_transfer(
                    x,
                    y_here,
                    y_donor,
                    self.bytes_per_token,
                    self.bandwidth_bytes_per_s,
                    self.per_call_s,
                    extra_blocks * self.calls_per_token_block,
                )
            }
            _ => false,
        };
        Ok(RouteOutcome {
            decision,
            expected_prefill_s,
            fetch_from_donor,
        })
    }

    /// Response path (paper Fig 6 right): the instance now caches the
    /// prompt + generated tokens.
    pub fn record_cached(&mut self, instance: InstanceId, tokens: &[u32],
                         now: f64) {
        self.trees.record(instance, tokens, now);
    }

    pub fn expire(&mut self, now: f64) {
        self.trees.expire(now);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gs(policy: PolicyKind) -> GlobalScheduler {
        let mut g = GlobalScheduler::new(
            policy,
            OperatorCostModel::paper_13b(),
            16,
            0.0,
        );
        g.bytes_per_token = 2 * 4 * 8 * 32 * 4;
        g.add_instance(InstanceId(0), InstanceKind::PrefillOnly);
        g.add_instance(InstanceId(1), InstanceKind::PrefillOnly);
        g.add_instance(InstanceId(2), InstanceKind::DecodeOnly);
        g
    }

    fn toks(n: usize, seed: u32) -> Vec<u32> {
        (0..n as u32).map(|i| i.wrapping_mul(31).wrapping_add(seed)).collect()
    }

    fn idle(_: InstanceId) -> InstanceLoad {
        InstanceLoad::default()
    }

    #[test]
    fn routes_to_cache_holder() {
        let mut g = gs(PolicyKind::PromptTree);
        let t = toks(256, 0);
        g.record_cached(InstanceId(1), &t, 1.0);
        let out = g.route(&t, 9, &idle, 2.0).unwrap();
        assert_eq!(out.decision.instance, InstanceId(1));
        assert_eq!(out.decision.matched_tokens, 256);
        assert!(!out.fetch_from_donor);
    }

    #[test]
    fn decode_only_never_chosen() {
        let mut g = gs(PolicyKind::LeastLoad);
        for s in 0..20 {
            let out = g.route(&toks(64, s), s as u64, &idle, 1.0).unwrap();
            assert_ne!(out.decision.instance, InstanceId(2));
        }
    }

    #[test]
    fn donor_transfer_engages_for_big_gap() {
        let mut g = gs(PolicyKind::PromptTree);
        g.bandwidth_bytes_per_s = 200e9;
        let t = toks(4096, 1);
        // Instance 0 has nearly everything cached but is overloaded, so
        // Eq. 1 picks instance 1; Eq. 2 should then fetch from 0.
        g.record_cached(InstanceId(0), &t, 1.0);
        let loads = |id: InstanceId| {
            if id == InstanceId(0) {
                InstanceLoad {
                    queued_tokens: 1_000_000,
                    ..Default::default()
                }
            } else {
                InstanceLoad::default()
            }
        };
        let out = g.route(&t, 3, &loads, 2.0).unwrap();
        assert_eq!(out.decision.instance, InstanceId(1));
        let (donor, donor_tokens) = out.decision.donor.unwrap();
        assert_eq!(donor, InstanceId(0));
        assert_eq!(donor_tokens, 4096);
        assert!(out.fetch_from_donor);
    }

    #[test]
    fn expected_prefill_reflects_cache() {
        let mut g = gs(PolicyKind::PromptTree);
        let t = toks(1024, 7);
        let cold = g.route(&t, 0, &idle, 1.0).unwrap().expected_prefill_s;
        g.record_cached(InstanceId(0), &t, 1.5);
        let warm = g.route(&t, 0, &idle, 2.0).unwrap().expected_prefill_s;
        assert!(warm < cold, "warm={warm} cold={cold}");
    }

    #[test]
    fn draining_instance_never_routed() {
        let mut g = gs(PolicyKind::PromptTree);
        let t = toks(256, 0);
        // Instance 1 holds the cache but is draining: routing must go
        // elsewhere even though the match is perfect.
        g.record_cached(InstanceId(1), &t, 1.0);
        g.trees.set_draining(InstanceId(1), true);
        for s in 0..10 {
            let out = g.route(&t, s, &idle, 2.0).unwrap();
            assert_ne!(out.decision.instance, InstanceId(1));
            // Nor may it appear as an Eq. 2 donor — migration, not
            // ad-hoc donor fetch, moves a draining instance's KV.
            assert!(out.decision.donor.is_none());
        }
        // Its view is still there for the migration planner.
        assert_eq!(g.trees.match_one(InstanceId(1), &t), 256);
    }

    #[test]
    fn capacity_pressure_steers_routing() {
        let mut g = gs(PolicyKind::PromptTree);
        let t = toks(1024, 2);
        // Both instances cache the prompt; 0 churns at full pressure.
        g.record_cached(InstanceId(0), &t, 1.0);
        g.record_cached(InstanceId(1), &t, 1.0);
        let loads = |id: InstanceId| InstanceLoad {
            capacity_pressure: if id == InstanceId(0) { 1.0 } else { 0.0 },
            ..Default::default()
        };
        let out = g.route(&t, 0, &loads, 2.0).unwrap();
        assert_eq!(out.decision.instance, InstanceId(1));
    }

    #[test]
    fn capped_emission_preserves_decisions_at_scale() {
        // 80 instances (> the 32-instance cap trigger), varied loads,
        // a few cache holders: capped and full emission must route
        // identically for the load-monotone policies.
        for policy in [PolicyKind::PromptTree, PolicyKind::LeastLoad] {
            let mk = |cold_sample: usize| {
                let mut g = GlobalScheduler::new(
                    policy,
                    OperatorCostModel::paper_13b(),
                    16,
                    0.0,
                );
                g.cold_sample = cold_sample;
                for i in 0..80 {
                    g.add_instance(InstanceId(i), InstanceKind::PrefillOnly);
                }
                g
            };
            let loads = |id: InstanceId| InstanceLoad {
                queued_tokens: ((id.0 as u64 * 2654435761) % 4096) as usize,
                ..Default::default()
            };
            let mut capped = mk(8);
            let mut full = mk(0);
            for s in 0..30u64 {
                let t = toks(256, (s % 5) as u32);
                if s < 3 {
                    capped.record_cached(InstanceId(s as u32 * 7), &t, 0.5);
                    full.record_cached(InstanceId(s as u32 * 7), &t, 0.5);
                }
                let a = capped.route(&t, s, &loads, 1.0).unwrap();
                let b = full.route(&t, s, &loads, 1.0).unwrap();
                assert_eq!(a.decision, b.decision, "policy {policy:?} s={s}");
            }
        }
    }

    #[test]
    fn transfer_decision_can_be_disabled() {
        let mut g = gs(PolicyKind::PromptTree);
        g.transfer_decision_enabled = false;
        g.bandwidth_bytes_per_s = 1e15;
        let t = toks(4096, 1);
        g.record_cached(InstanceId(0), &t, 1.0);
        let loads = |id: InstanceId| InstanceLoad {
            queued_tokens: if id == InstanceId(0) { 1_000_000 } else { 0 },
            ..Default::default()
        };
        let out = g.route(&t, 3, &loads, 2.0).unwrap();
        assert!(!out.fetch_from_donor);
    }
}
