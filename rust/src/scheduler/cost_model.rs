//! Context-caching cost model (paper §5.3): predict prefill time
//! `exec(x, y)` for prompt length `x` with cached ratio `y`, plus the
//! Eq. 2 transfer-vs-recompute decision.
//!
//! Two models are implemented, mirroring the paper's comparison (Fig 14):
//!
//! * **Operator-level** (the paper's choice): per-operator costs fit from
//!   profiles — compute-bound ops use the wave model
//!   `(η−1)·T_fullwave + T_lastwave`; the memory-bound prefix attention
//!   uses `a·x²·y + b·x² + c·x + d` (FlashAttention-2 form); constant ops
//!   (norm/activation) are a linear floor. TP/PP scaling multiplies the
//!   parallel terms only, which is why operator-level transfers across
//!   parallelism configs while arch-level does not.
//! * **Arch-level** baseline: a single polynomial fit of end-to-end TTFT,
//!   which must be recalibrated per configuration (Amdahl's law breaks
//!   naive rescaling — the paper measures ~20% error at TP=2).

/// Operator-level cost model. All times in seconds; x in tokens; y in
/// [0,1] (fraction of the prompt whose KV is already cached).
#[derive(Clone, Debug, PartialEq)]
pub struct OperatorCostModel {
    /// Memory-bound prefix attention: a·x²·y + b·x² + c·x + d.
    pub attn_a: f64,
    pub attn_b: f64,
    pub attn_c: f64,
    pub attn_d: f64,
    /// Compute-bound GEMMs (QKV/O/MLP): wave model, linear in uncached
    /// tokens: per-token FLOP time (parallel part).
    pub gemm_per_token: f64,
    /// Wave quantization: tokens per "full wave" (SM count × tile rows).
    pub wave_tokens: usize,
    /// Optional explicit compute buckets (static-shape AOT runtimes pad
    /// the new tokens up to a compiled bucket; this is wave quantization
    /// at bucket granularity). Empty = use the uniform wave model.
    pub buckets: Vec<usize>,
    /// Optional per-bucket measured compute cost (seconds), parallel to
    /// `buckets`. When present it replaces slope×padded — the paper's
    /// "profile one compute-bound operator" made exact per shape.
    pub bucket_costs: Vec<f64>,
    /// Per-cached-token cost of consuming the historical KV (reading
    /// cached keys in prefix attention + staging the cache input). The
    /// paper's a·x²·y term captures this at GPU scale; at small scale it
    /// is linear. Must stay well below gemm_per_token for caching to pay.
    pub cached_per_token: f64,
    /// Constant/serial per-prefill overhead (norms, activations, launch).
    pub constant: f64,
    /// Tensor-parallel degree the parallel terms are divided by.
    pub tp: usize,
    /// Decode step: base + per-context-token cost (memory-bound GEMV).
    pub decode_base: f64,
    pub decode_per_ctx_token: f64,
}

impl OperatorCostModel {
    /// Calibration constants roughly matching our PJRT-CPU tiny model
    /// (see `calibrate` in the launcher; benches overwrite from
    /// artifacts/cost_model.json when present).
    pub fn default_tiny() -> Self {
        OperatorCostModel {
            attn_a: -1.1e-8,
            attn_b: 1.2e-8,
            attn_c: 3.0e-6,
            attn_d: 2.0e-4,
            gemm_per_token: 3.5e-5,
            wave_tokens: 64,
            buckets: vec![],
            bucket_costs: vec![],
            cached_per_token: 3.0e-5,
            constant: 1.0e-3,
            tp: 1,
            decode_base: 2.0e-3,
            decode_per_ctx_token: 4.0e-6,
        }
    }

    /// Paper-scale constants (Llama2-13B-class on an H800, TP=2),
    /// derived from the paper's reported TTFTs; used by the simulator so
    /// the Fig 8/12/15 sweeps run at realistic magnitudes.
    pub fn paper_13b() -> Self {
        OperatorCostModel {
            attn_a: -1.05e-8,
            attn_b: 1.15e-8,
            attn_c: 1.1e-5,
            attn_d: 1.0e-3,
            gemm_per_token: 4.5e-5,
            wave_tokens: 132 * 2, // SMs × rows per wave
            buckets: vec![],
            bucket_costs: vec![],
            cached_per_token: 0.0, // folded into attn_a at GPU scale
            constant: 4.0e-3,
            tp: 2,
            decode_base: 1.6e-2,
            decode_per_ctx_token: 6.0e-6,
        }
    }

    /// Predict prefill time for prompt `x` tokens, cached ratio `y`.
    pub fn exec(&self, x: usize, y: f64) -> f64 {
        let y = y.clamp(0.0, 1.0);
        let xf = x as f64;
        // New (uncached) tokens drive the compute-bound ops.
        let new_tokens = xf * (1.0 - y);
        // Wave quantization (paper §5.3.2a): uniform waves, or explicit
        // compiled-bucket padding when the runtime is AOT-bucketized.
        let gemm = if self.buckets.is_empty() {
            let padded = (new_tokens / self.wave_tokens as f64)
                .ceil()
                .max(0.0)
                * self.wave_tokens as f64;
            padded * self.gemm_per_token
        } else {
            // Smallest compiled bucket that fits the new tokens.
            let idx = self
                .buckets
                .iter()
                .position(|&b| b as f64 >= new_tokens)
                .unwrap_or(self.buckets.len() - 1);
            match self.bucket_costs.get(idx) {
                Some(&c) => c, // per-bucket profile
                None => self.buckets[idx] as f64 * self.gemm_per_token,
            }
        };
        // Memory-bound prefix attention (paper §5.3.2b): note a < 0 —
        // caching *reduces* the x² term (cached keys are read, not
        // recomputed), so attn cost falls with y.
        let attn = self.attn_a * xf * xf * y + self.attn_b * xf * xf
            + self.attn_c * new_tokens
            + self.attn_d;
        let cache_read = self.cached_per_token * xf * y;
        (gemm + attn + cache_read) / self.tp as f64 + self.constant
    }

    /// One decode step at context length `ctx`.
    pub fn decode_step(&self, ctx: usize) -> f64 {
        self.decode_base / self.tp as f64
            + self.decode_per_ctx_token * ctx as f64 / self.tp as f64
    }

    /// Rescale the parallel terms for a different TP degree — the
    /// operator-level model's scalability trick (paper §5.3.2).
    pub fn with_tp(&self, tp: usize) -> Self {
        let mut m = self.clone();
        m.tp = tp.max(1);
        m
    }

    /// Eq. 2: should we transfer `extra` cached tokens from a donor
    /// instead of recomputing them? True = transfer.
    ///
    /// transfer(y, y') <= exec(x, y) - exec(x, y')
    pub fn should_transfer(
        &self,
        x: usize,
        y_here: f64,
        y_donor: f64,
        bytes_per_token: usize,
        bandwidth_bytes_per_s: f64,
        per_call_s: f64,
        calls: usize,
    ) -> bool {
        if y_donor <= y_here {
            return false;
        }
        let extra_tokens = (x as f64 * (y_donor - y_here)).round();
        let transfer_s = extra_tokens * bytes_per_token as f64
            / bandwidth_bytes_per_s
            + per_call_s * calls as f64;
        let saved = self.exec(x, y_here) - self.exec(x, y_donor);
        transfer_s <= saved
    }
}

/// Occupancy where a pool starts churning: entries inserted near
/// capacity evict other entries, and the matched prefix a route counted
/// on may be gone before the request reaches the head of the queue.
pub const PRESSURE_KNEE: f64 = 0.75;

/// Capacity-pressure discount on a matched cached ratio (Eq. 1's
/// locality term): multiplier in `[0.5, 1]`, 1 below [`PRESSURE_KNEE`]
/// occupancy, falling linearly to 0.5 at a full pool. An instance near
/// eviction churn is a worse cache holder than its matched length
/// suggests — both the router (`policy::decide`) and the migration
/// planner's recipient ranking lean on this signal, so it lives here
/// next to the rest of the §5.3 cost model.
pub fn pressure_discount(pressure: f64) -> f64 {
    const MAX_DISCOUNT: f64 = 0.5;
    let p = pressure.clamp(0.0, 1.0);
    if p <= PRESSURE_KNEE {
        1.0
    } else {
        1.0 - MAX_DISCOUNT * (p - PRESSURE_KNEE) / (1.0 - PRESSURE_KNEE)
    }
}

/// Arch-level baseline: fit TTFT = p0 + p1·x + p2·x² scaled by (1-y),
/// calibrated at ONE parallelism config (paper Fig 14b shows why this
/// generalizes poorly).
#[derive(Clone, Debug)]
pub struct ArchCostModel {
    pub p0: f64,
    pub p1: f64,
    pub p2: f64,
    /// The TP the fit was made at; rescaling divides everything (the
    /// naive — and wrong under Amdahl — adjustment).
    pub fitted_tp: usize,
}

impl ArchCostModel {
    /// Least-squares fit from (x, y, t) samples.
    pub fn fit(samples: &[(usize, f64, f64)], fitted_tp: usize) -> Self {
        // Model t = p0 + p1·u + p2·u² with u = x·(1−y): 3-param normal
        // equations.
        let mut ata = [[0.0f64; 3]; 3];
        let mut atb = [0.0f64; 3];
        for &(x, y, t) in samples {
            let u = x as f64 * (1.0 - y);
            let row = [1.0, u, u * u];
            for i in 0..3 {
                for j in 0..3 {
                    ata[i][j] += row[i] * row[j];
                }
                atb[i] += row[i] * t;
            }
        }
        let p = solve3(ata, atb);
        ArchCostModel {
            p0: p[0],
            p1: p[1],
            p2: p[2],
            fitted_tp,
        }
    }

    pub fn exec(&self, x: usize, y: f64) -> f64 {
        let u = x as f64 * (1.0 - y.clamp(0.0, 1.0));
        (self.p0 + self.p1 * u + self.p2 * u * u).max(0.0)
    }

    /// Naive TP rescale (divide everything) — exactly what the paper
    /// criticizes: serial parts get wrongly divided too.
    pub fn exec_rescaled(&self, x: usize, y: f64, tp: usize) -> f64 {
        self.exec(x, y) * self.fitted_tp as f64 / tp.max(1) as f64
    }
}

/// Serialize a calibrated model (the `calibrate` launcher command writes
/// this to `artifacts/cost_model.json`).
pub fn model_to_json(m: &OperatorCostModel) -> crate::util::json::Json {
    use crate::util::json::Json;
    Json::obj(vec![
        ("attn_a", Json::num(m.attn_a)),
        ("attn_b", Json::num(m.attn_b)),
        ("attn_c", Json::num(m.attn_c)),
        ("attn_d", Json::num(m.attn_d)),
        ("gemm_per_token", Json::num(m.gemm_per_token)),
        ("wave_tokens", Json::num(m.wave_tokens as f64)),
        ("buckets", Json::arr(
            m.buckets.iter().map(|&b| Json::num(b as f64)).collect(),
        )),
        ("bucket_costs", Json::arr(
            m.bucket_costs.iter().map(|&c| Json::num(c)).collect(),
        )),
        ("cached_per_token", Json::num(m.cached_per_token)),
        ("constant", Json::num(m.constant)),
        ("tp", Json::num(m.tp as f64)),
        ("decode_base", Json::num(m.decode_base)),
        ("decode_per_ctx_token", Json::num(m.decode_per_ctx_token)),
    ])
}

/// Deserialize a calibrated model; `None` on any missing field.
pub fn model_from_json(j: &crate::util::json::Json)
                       -> Option<OperatorCostModel> {
    let f = |k: &str| j.get(k)?.as_f64();
    Some(OperatorCostModel {
        attn_a: f("attn_a")?,
        attn_b: f("attn_b")?,
        attn_c: f("attn_c")?,
        attn_d: f("attn_d")?,
        gemm_per_token: f("gemm_per_token")?,
        wave_tokens: f("wave_tokens")? as usize,
        buckets: j
            .get("buckets")
            .and_then(|b| b.as_arr())
            .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
            .unwrap_or_default(),
        bucket_costs: j
            .get("bucket_costs")
            .and_then(|b| b.as_arr())
            .map(|a| a.iter().filter_map(|x| x.as_f64()).collect())
            .unwrap_or_default(),
        cached_per_token: j
            .get("cached_per_token")
            .and_then(|x| x.as_f64())
            .unwrap_or(0.0),
        constant: f("constant")?,
        tp: f("tp")? as usize,
        decode_base: f("decode_base")?,
        decode_per_ctx_token: f("decode_per_ctx_token")?,
    })
}

/// Solve a 3×3 linear system by Gaussian elimination with partial pivot.
fn solve3(mut a: [[f64; 3]; 3], mut b: [f64; 3]) -> [f64; 3] {
    for col in 0..3 {
        let piv = (col..3)
            .max_by(|&i, &j| {
                a[i][col].abs().partial_cmp(&a[j][col].abs()).unwrap()
            })
            .unwrap();
        a.swap(col, piv);
        b.swap(col, piv);
        let d = a[col][col];
        if d.abs() < 1e-30 {
            continue;
        }
        for row in 0..3 {
            if row == col {
                continue;
            }
            let f = a[row][col] / d;
            for k in 0..3 {
                a[row][k] -= f * a[col][k];
            }
            b[row] -= f * b[col];
        }
    }
    let mut out = [0.0; 3];
    for i in 0..3 {
        out[i] = if a[i][i].abs() < 1e-30 {
            0.0
        } else {
            b[i] / a[i][i]
        };
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exec_decreases_with_cached_ratio() {
        let m = OperatorCostModel::paper_13b();
        for x in [256usize, 1024, 4096] {
            let mut prev = f64::INFINITY;
            for yi in 0..=10 {
                let y = yi as f64 / 10.0;
                let t = m.exec(x, y);
                assert!(t > 0.0);
                assert!(t <= prev + 1e-12, "exec not monotone at x={x} y={y}");
                prev = t;
            }
        }
    }

    #[test]
    fn exec_increases_with_prompt_length() {
        let m = OperatorCostModel::paper_13b();
        let a = m.exec(256, 0.5);
        let b = m.exec(1024, 0.5);
        let c = m.exec(4096, 0.5);
        assert!(a < b && b < c);
    }

    #[test]
    fn longer_prompts_gain_more_from_caching() {
        // Paper Fig 13a: improvement grows with prompt length.
        let m = OperatorCostModel::paper_13b();
        let improvement = |x: usize| {
            let t0 = m.exec(x, 0.0);
            let t9 = m.exec(x, 0.9);
            (t0 - t9) / t0
        };
        assert!(improvement(4096) > improvement(512));
    }

    #[test]
    fn tp_scaling_is_sublinear() {
        // Amdahl: TP=2 must NOT halve exec (constant term is serial).
        let m1 = OperatorCostModel::paper_13b().with_tp(1);
        let m2 = m1.with_tp(2);
        let t1 = m1.exec(2048, 0.0);
        let t2 = m2.exec(2048, 0.0);
        assert!(t2 < t1);
        assert!(t2 > t1 / 2.0, "TP=2 halved exec exactly — no serial part?");
    }

    #[test]
    fn transfer_decision_prefers_transfer_for_long_prompts() {
        let m = OperatorCostModel::paper_13b();
        // 4K-token prompt, donor has 87.5% cached, NVLink-class fabric.
        let bytes_per_token = 2 * 40 * 40 * 128 * 2; // 13B-ish KV/token
        let yes = m.should_transfer(
            4096, 0.0, 0.875, bytes_per_token, 200e9, 15e-6, 256,
        );
        assert!(yes, "fast link + big saving must favor transfer");
        // Same saving over a 100 MB/s link: recompute wins.
        let no = m.should_transfer(
            4096, 0.0, 0.875, bytes_per_token, 100e6, 15e-6, 256,
        );
        assert!(!no, "slow link must favor recompute");
    }

    #[test]
    fn transfer_decision_requires_larger_donor_ratio() {
        let m = OperatorCostModel::paper_13b();
        assert!(!m.should_transfer(1024, 0.5, 0.5, 1000, 1e12, 0.0, 1));
        assert!(!m.should_transfer(1024, 0.6, 0.5, 1000, 1e12, 0.0, 1));
    }

    #[test]
    fn arch_fit_recovers_its_own_data() {
        let truth = OperatorCostModel::paper_13b();
        let mut samples = vec![];
        for x in (256..=4096).step_by(256) {
            for yi in 0..=4 {
                let y = yi as f64 / 4.0;
                samples.push((x, y, truth.exec(x, y)));
            }
        }
        let arch = ArchCostModel::fit(&samples, 2);
        // The arch model compresses (x, y) into u = x·(1−y), which cannot
        // represent the cached-attention x²-term — in-distribution error
        // is bounded but visibly worse than the operator model (the
        // paper's point). Empirically mean ≈ 15%, max ≈ 49% on this grid.
        let mut mean_rel = 0.0f64;
        let mut max_rel = 0.0f64;
        for &(x, y, t) in &samples {
            let rel = (arch.exec(x, y) - t).abs() / t;
            mean_rel += rel;
            max_rel = max_rel.max(rel);
        }
        mean_rel /= samples.len() as f64;
        assert!(mean_rel < 0.25, "arch fit mean error too big: {mean_rel}");
        assert!(max_rel < 0.80, "arch fit max error too big: {max_rel}");
        assert!(
            mean_rel > 0.02,
            "arch model should NOT fit the cached cases well \
             (misspecification is the point): {mean_rel}"
        );
    }

    #[test]
    fn arch_rescale_is_worse_than_operator_rescale() {
        // Fig 14b's story: fit both at TP=2, predict TP=1.
        let truth_tp2 = OperatorCostModel::paper_13b(); // tp = 2
        let truth_tp1 = truth_tp2.with_tp(1);
        let mut samples = vec![];
        for x in (256..=4096).step_by(256) {
            samples.push((x, 0.0, truth_tp2.exec(x, 0.0)));
        }
        let arch = ArchCostModel::fit(&samples, 2);
        let x = 2048;
        let true_t = truth_tp1.exec(x, 0.0);
        let op_pred = truth_tp2.with_tp(1).exec(x, 0.0); // operator rescale
        let arch_pred = arch.exec_rescaled(x, 0.0, 1);
        let op_err = (op_pred - true_t).abs() / true_t;
        let arch_err = (arch_pred - true_t).abs() / true_t;
        assert!(op_err < 1e-9);
        assert!(
            arch_err > 0.02,
            "naive arch rescale should mispredict ({arch_err})"
        );
    }

    #[test]
    fn pressure_discount_shape() {
        // No discount below the knee; monotone to 0.5 at full pressure.
        assert_eq!(pressure_discount(0.0), 1.0);
        assert_eq!(pressure_discount(PRESSURE_KNEE), 1.0);
        assert_eq!(pressure_discount(1.0), 0.5);
        let mid = pressure_discount((PRESSURE_KNEE + 1.0) / 2.0);
        assert!(mid < 1.0 && mid > 0.5);
        // Clamped outside [0, 1].
        assert_eq!(pressure_discount(-3.0), 1.0);
        assert_eq!(pressure_discount(9.0), 0.5);
    }

    #[test]
    fn pressure_raises_expected_exec() {
        let m = OperatorCostModel::paper_13b();
        let cold = m.exec(2048, 0.8 * pressure_discount(1.0));
        let calm = m.exec(2048, 0.8 * pressure_discount(0.0));
        assert!(
            cold > calm,
            "full pressure must discount the cache benefit"
        );
    }

    #[test]
    fn decode_cost_grows_with_context() {
        let m = OperatorCostModel::paper_13b();
        assert!(m.decode_step(4096) > m.decode_step(128));
    }

    #[test]
    fn solve3_known_system() {
        let a = [[2.0, 0.0, 0.0], [0.0, 3.0, 0.0], [1.0, 0.0, 1.0]];
        let b = [4.0, 9.0, 5.0];
        let x = solve3(a, b);
        assert!((x[0] - 2.0).abs() < 1e-9);
        assert!((x[1] - 3.0).abs() < 1e-9);
        assert!((x[2] - 3.0).abs() < 1e-9);
    }
}
