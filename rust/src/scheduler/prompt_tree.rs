//! Global prompt trees (paper §6, Fig 6).
//!
//! The global scheduler keeps one radix tree per inference instance,
//! grouped by instance type (prefill-only / decode-only / PD-colocated).
//! Trees reuse [`crate::mempool::RadixIndex`]; the "extra field pointing
//! to the instance" from the paper is the per-tree instance tag. Global
//! trees store no block addresses (the GS never touches data) — they
//! track *which tokens* an instance has cached, with a TTL because the GS
//! only learns about inserts, never local evictions (best-effort, §6
//! Discussion).

use std::collections::BTreeMap;

use crate::mempool::{InstanceId, RadixIndex};

/// Instance roles, mirroring Figure 1.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum InstanceKind {
    PrefillOnly,
    DecodeOnly,
    Colocated,
}

impl InstanceKind {
    /// Does this instance run prefill (and thus serve cached prefixes)?
    pub fn runs_prefill(self) -> bool {
        !matches!(self, InstanceKind::DecodeOnly)
    }
}

struct TreeEntry {
    kind: InstanceKind,
    tree: RadixIndex,
}

/// All global prompt trees, keyed by instance.
pub struct GlobalPromptTrees {
    trees: BTreeMap<InstanceId, TreeEntry>,
    block_tokens: usize,
    ttl: f64,
}

impl GlobalPromptTrees {
    pub fn new(block_tokens: usize, ttl: f64) -> Self {
        GlobalPromptTrees {
            trees: BTreeMap::new(),
            block_tokens,
            ttl,
        }
    }

    pub fn add_instance(&mut self, id: InstanceId, kind: InstanceKind) {
        self.trees.insert(
            id,
            TreeEntry {
                kind,
                tree: RadixIndex::new(self.block_tokens, self.ttl),
            },
        );
    }

    /// Drop a failed/removed instance's tree (paper §4.4: membership
    /// change broadcast).
    pub fn remove_instance(&mut self, id: InstanceId) {
        self.trees.remove(&id);
    }

    pub fn instances(&self) -> Vec<(InstanceId, InstanceKind)> {
        self.trees.iter().map(|(k, v)| (*k, v.kind)).collect()
    }

    pub fn kind_of(&self, id: InstanceId) -> Option<InstanceKind> {
        self.trees.get(&id).map(|e| e.kind)
    }

    /// Record that `instance` now caches `tokens` (called on the response
    /// path — paper Fig 6 update path).
    pub fn record(&mut self, instance: InstanceId, tokens: &[u32], now: f64) {
        let Some(e) = self.trees.get_mut(&instance) else {
            return;
        };
        // Global trees carry no addresses — address-free insert.
        e.tree.insert_unaddressed(tokens, now);
    }

    /// Matched prefix length (tokens) of `tokens` on every prefill-capable
    /// instance — the parallel match step of the scheduling path.
    pub fn match_all(&mut self, tokens: &[u32], now: f64)
                     -> Vec<(InstanceId, usize)> {
        self.trees
            .iter_mut()
            .filter(|(_, e)| e.kind.runs_prefill())
            .map(|(id, e)| (*id, e.tree.match_prefix(tokens, now).tokens))
            .collect()
    }

    /// Matched prefix on one specific instance.
    pub fn match_one(&mut self, id: InstanceId, tokens: &[u32], now: f64)
                     -> usize {
        self.trees
            .get_mut(&id)
            .map(|e| e.tree.match_prefix(tokens, now).tokens)
            .unwrap_or(0)
    }

    /// TTL housekeeping over all trees.
    pub fn expire(&mut self, now: f64) {
        for e in self.trees.values_mut() {
            e.tree.expire(now);
        }
    }

    /// Total cached token-blocks believed to exist per instance.
    pub fn cached_blocks(&self, id: InstanceId) -> usize {
        self.trees
            .get(&id)
            .map(|e| e.tree.total_token_blocks())
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(n: usize, seed: u32) -> Vec<u32> {
        (0..n as u32).map(|i| i * 3 + seed).collect()
    }

    #[test]
    fn record_and_match() {
        let mut g = GlobalPromptTrees::new(16, 0.0);
        g.add_instance(InstanceId(0), InstanceKind::PrefillOnly);
        g.add_instance(InstanceId(1), InstanceKind::PrefillOnly);
        let t = toks(64, 0);
        g.record(InstanceId(1), &t, 1.0);
        let m = g.match_all(&t, 2.0);
        assert_eq!(m, vec![(InstanceId(0), 0), (InstanceId(1), 64)]);
    }

    #[test]
    fn decode_only_excluded_from_prefill_match() {
        let mut g = GlobalPromptTrees::new(16, 0.0);
        g.add_instance(InstanceId(0), InstanceKind::PrefillOnly);
        g.add_instance(InstanceId(1), InstanceKind::DecodeOnly);
        let t = toks(32, 0);
        g.record(InstanceId(1), &t, 1.0);
        let m = g.match_all(&t, 2.0);
        assert_eq!(m.len(), 1);
        assert_eq!(m[0].0, InstanceId(0));
        // But the decode tree still answers match_one (used for D-side
        // incremental transfer decisions).
        assert_eq!(g.match_one(InstanceId(1), &t, 2.0), 32);
    }

    #[test]
    fn ttl_staleness() {
        let mut g = GlobalPromptTrees::new(16, 10.0);
        g.add_instance(InstanceId(0), InstanceKind::Colocated);
        let t = toks(32, 5);
        g.record(InstanceId(0), &t, 0.0);
        g.expire(20.0);
        assert_eq!(g.match_one(InstanceId(0), &t, 21.0), 0);
    }

    #[test]
    fn remove_instance_forgets() {
        let mut g = GlobalPromptTrees::new(16, 0.0);
        g.add_instance(InstanceId(0), InstanceKind::PrefillOnly);
        let t = toks(16, 1);
        g.record(InstanceId(0), &t, 1.0);
        g.remove_instance(InstanceId(0));
        assert!(g.match_all(&t, 2.0).is_empty());
    }

    #[test]
    fn partial_blocks_rounded_down() {
        let mut g = GlobalPromptTrees::new(16, 0.0);
        g.add_instance(InstanceId(0), InstanceKind::PrefillOnly);
        g.record(InstanceId(0), &toks(20, 0), 1.0);
        assert_eq!(g.match_one(InstanceId(0), &toks(20, 0), 2.0), 16);
        assert_eq!(g.cached_blocks(InstanceId(0)), 1);
    }
}
