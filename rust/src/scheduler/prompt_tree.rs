//! Global prompt trees (paper §6, Fig 6).
//!
//! The global scheduler tracks *which tokens* each instance has cached
//! (never addresses — the GS touches no data) and matches every incoming
//! prompt against that view on the scheduling path. Entries carry a TTL
//! because the GS only learns about inserts, never local evictions
//! (best-effort, §6 Discussion).
//!
//! Since the fused-tree overhaul, [`GlobalPromptTrees`] is a single
//! shared radix tree whose nodes carry per-instance ownership bitsets
//! ([`crate::scheduler::fused_tree::FusedPromptTree`]): one walk yields
//! the matched prefix for the whole fleet, O(prompt_blocks) regardless
//! of instance count. The paper's "extra field pointing to the instance"
//! is the ownership bit; the per-instance-tree seed layout survives in
//! [`crate::scheduler::prompt_tree_ref`] for differential testing and
//! benchmarking.

pub use crate::scheduler::fused_tree::FusedPromptTree as GlobalPromptTrees;
use crate::mempool::InstanceId;

/// Instance roles, mirroring Figure 1.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum InstanceKind {
    PrefillOnly,
    DecodeOnly,
    Colocated,
}

impl InstanceKind {
    /// Does this instance run prefill (and thus serve cached prefixes)?
    pub fn runs_prefill(self) -> bool {
        !matches!(self, InstanceKind::DecodeOnly)
    }
}

/// Convenience for tests and non-hot-path callers: allocate and return
/// the matched-prefix vector. The scheduling path uses
/// [`GlobalPromptTrees::match_into`] with a reused buffer instead.
pub fn match_all_vec(
    trees: &mut GlobalPromptTrees,
    tokens: &[u32],
) -> Vec<(InstanceId, usize)> {
    let mut out = vec![];
    trees.match_into(tokens, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(n: usize, seed: u32) -> Vec<u32> {
        (0..n as u32).map(|i| i * 3 + seed).collect()
    }

    #[test]
    fn record_and_match() {
        let mut g = GlobalPromptTrees::new(16, 0.0);
        g.add_instance(InstanceId(0), InstanceKind::PrefillOnly);
        g.add_instance(InstanceId(1), InstanceKind::PrefillOnly);
        let t = toks(64, 0);
        g.record(InstanceId(1), &t, 1.0);
        let m = match_all_vec(&mut g, &t);
        assert_eq!(m, vec![(InstanceId(0), 0), (InstanceId(1), 64)]);
    }

    #[test]
    fn decode_only_excluded_from_prefill_match() {
        let mut g = GlobalPromptTrees::new(16, 0.0);
        g.add_instance(InstanceId(0), InstanceKind::PrefillOnly);
        g.add_instance(InstanceId(1), InstanceKind::DecodeOnly);
        let t = toks(32, 0);
        g.record(InstanceId(1), &t, 1.0);
        let m = match_all_vec(&mut g, &t);
        assert_eq!(m.len(), 1);
        assert_eq!(m[0].0, InstanceId(0));
        // But the shared tree still answers match_one for decode
        // instances (used for D-side incremental transfer decisions).
        assert_eq!(g.match_one(InstanceId(1), &t), 32);
    }

    #[test]
    fn ttl_staleness() {
        let mut g = GlobalPromptTrees::new(16, 10.0);
        g.add_instance(InstanceId(0), InstanceKind::Colocated);
        let t = toks(32, 5);
        g.record(InstanceId(0), &t, 0.0);
        g.expire(20.0);
        assert_eq!(g.match_one(InstanceId(0), &t), 0);
    }

    #[test]
    fn remove_instance_forgets() {
        let mut g = GlobalPromptTrees::new(16, 0.0);
        g.add_instance(InstanceId(0), InstanceKind::PrefillOnly);
        let t = toks(16, 1);
        g.record(InstanceId(0), &t, 1.0);
        g.remove_instance(InstanceId(0));
        assert!(match_all_vec(&mut g, &t).is_empty());
    }

    #[test]
    fn partial_blocks_rounded_down() {
        let mut g = GlobalPromptTrees::new(16, 0.0);
        g.add_instance(InstanceId(0), InstanceKind::PrefillOnly);
        g.record(InstanceId(0), &toks(20, 0), 1.0);
        assert_eq!(g.match_one(InstanceId(0), &toks(20, 0)), 16);
        assert_eq!(g.cached_blocks(InstanceId(0)), 1);
    }

    #[test]
    fn instances_iterates_in_id_order() {
        let mut g = GlobalPromptTrees::new(16, 0.0);
        g.add_instance(InstanceId(2), InstanceKind::DecodeOnly);
        g.add_instance(InstanceId(0), InstanceKind::PrefillOnly);
        g.add_instance(InstanceId(1), InstanceKind::Colocated);
        let got: Vec<_> = g.instances().collect();
        assert_eq!(got, vec![
            (InstanceId(0), InstanceKind::PrefillOnly),
            (InstanceId(1), InstanceKind::Colocated),
            (InstanceId(2), InstanceKind::DecodeOnly),
        ]);
        assert_eq!(g.kind_of(InstanceId(2)), Some(InstanceKind::DecodeOnly));
        assert_eq!(g.kind_of(InstanceId(9)), None);
    }
}
