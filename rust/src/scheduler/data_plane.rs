//! Per-shard scheduler worker threads — the multi-core data plane
//! (ISSUE 7 tentpole, scheduler half).
//!
//! PR 5 made the S shards *independent* (one tree + one delta log per
//! fingerprint range) but left them behind one owner: every route and
//! every delta still serialized through a single `&mut` holder. This
//! module pins each shard to its own OS thread so writes actually
//! scale by cores × shards:
//!
//! ```text
//!  submitters (T threads, cloned ShardSubmitter)
//!     │ route(prompt) ── ShardMap: first-block fingerprint → shard k
//!     ▼
//!  ┌─────────┐  ┌─────────┐       ┌─────────┐
//!  │worker 0 │  │worker 1 │  ...  │worker S-1│   one thread per shard,
//!  │ 1-shard │  │ 1-shard │       │ 1-shard │   owning its tree +
//!  │   GS    │  │   GS    │       │   GS    │   load book outright
//!  └─────────┘  └─────────┘       └─────────┘
//!     ▲  MPSC channel per worker (routes + One(k) deltas, FIFO)
//!     │
//!  ShardWorkerPool ── All-shard events (membership, whole-view
//!                     expiry) broadcast + epoch fence
//!                     (`util::sync::EpochGate`, loom-modeled)
//! ```
//!
//! **Lock-free vs epoch-fenced.** The submit path takes no lock at
//! all: a route or a prefix-keyed delta is one channel send to its
//! shard's worker, and each worker owns its `GlobalScheduler` without
//! synchronization (single-consumer). Cross-shard operations —
//! `Join`/`Leave`/`SetDraining` fan-out and whole-view expiries — are
//! epoch-fenced broadcasts: the pool bumps its epoch, enqueues the
//! event plus a `Fence` on every worker's FIFO channel, and blocks
//! until every worker acks the epoch. Channel FIFO order makes the
//! fence a happens-after barrier for everything enqueued before it, so
//! when `broadcast` returns every shard has applied the membership
//! change (the same registry-agreement invariant
//! `ShardedPromptTrees::debug_check_counters` checks in-process).
//!
//! **Why per-shard decisions stay deterministic.** Each worker's
//! 1-shard scheduler sees exactly the deltas `ShardMap` routes to it,
//! in channel order, plus every broadcast — which is precisely the
//! slice the monolithic S-shard scheduler's shard-k tree sees, in the
//! same order. A `Route` carries the full per-instance load vector, so
//! the load book state a decision reads is a function of that request
//! alone, not of cross-shard interleaving. Hence: per-shard decision
//! streams are a pure function of (seeded tree state, request), and a
//! T-thread run must agree request-for-request with the single-thread
//! reference — the differential property pinned below.

use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::elastic::delta::DeltaEvent;
use crate::mempool::InstanceId;
use crate::obs::Registry;
use crate::scheduler::cost_model::OperatorCostModel;
use crate::scheduler::policy::{Decision, PolicyKind};
use crate::scheduler::router::{GlobalScheduler, InstanceLoad, RouteOutcome};
use crate::util::sync::EpochGate;
use crate::scheduler::shard::{ShardMap, ShardRoute};

/// Per-route load snapshot: the full fleet's loads, shared (not
/// cloned) into the request so decisions are a function of the request
/// alone — see module docs.
pub type LoadVec = Arc<Vec<(InstanceId, InstanceLoad)>>;

enum ShardRequest {
    /// Route one request on this shard (it owns the prompt's prefix
    /// chain). Replies on the provided one-shot channel.
    Route {
        id: u64,
        prompt: Vec<u32>,
        session: u64,
        now: f64,
        loads: LoadVec,
        reply: Sender<anyhow::Result<RouteOutcome>>,
    },
    /// Apply one delta to this shard's tree (One(k)-routed, or one leg
    /// of an All broadcast).
    Delta(DeltaEvent),
    /// Ack `epoch` on the shared board once everything enqueued before
    /// this request has been applied.
    Fence { epoch: u64 },
    /// Return the (request id, decision) log in processing order.
    Collect {
        reply: Sender<Vec<(u64, Decision)>>,
    },
    Stop,
}

fn worker_loop(
    shard: usize,
    rx: Receiver<ShardRequest>,
    mut gs: GlobalScheduler,
    acks: Arc<EpochGate>,
) {
    let mut log: Vec<(u64, Decision)> = vec![];
    while let Ok(req) = rx.recv() {
        match req {
            ShardRequest::Route {
                id,
                prompt,
                session,
                now,
                loads,
                reply,
            } => {
                for &(inst, load) in loads.iter() {
                    gs.set_load(inst, load);
                }
                let out = gs.route(&prompt, session, now);
                if let Ok(o) = &out {
                    log.push((id, o.decision.clone()));
                }
                let _ = reply.send(out);
            }
            ShardRequest::Delta(ev) => gs.trees.apply_delta(&ev),
            ShardRequest::Fence { epoch } => acks.ack(shard, epoch),
            ShardRequest::Collect { reply } => {
                let _ = reply.send(log.clone());
            }
            ShardRequest::Stop => break,
        }
    }
}

/// S shard-pinned worker threads behind a `ShardMap`-routed submit
/// path (see module docs). Created with the same scheduler knobs every
/// worker shares; each worker owns a 1-shard [`GlobalScheduler`].
pub struct ShardWorkerPool {
    senders: Vec<Sender<ShardRequest>>,
    handles: Vec<JoinHandle<()>>,
    map: ShardMap,
    epoch: u64,
    acks: Arc<EpochGate>,
}

impl ShardWorkerPool {
    pub fn new(
        shards: usize,
        block_tokens: usize,
        ttl: f64,
        policy: PolicyKind,
        cost: OperatorCostModel,
    ) -> Self {
        Self::new_with_obs(shards, block_tokens, ttl, policy, cost, None)
    }

    /// Like [`Self::new`], with each worker's scheduler registering
    /// its route-path metrics (labeled `shard=k`) into `reg` before
    /// the thread starts (ISSUE 8). Handles resolve once; the workers'
    /// submit path stays lock-free.
    pub fn new_with_obs(
        shards: usize,
        block_tokens: usize,
        ttl: f64,
        policy: PolicyKind,
        cost: OperatorCostModel,
        reg: Option<&Registry>,
    ) -> Self {
        assert!(shards >= 1, "at least one shard");
        let acks = Arc::new(EpochGate::new(shards));
        let mut senders = Vec::with_capacity(shards);
        let mut handles = Vec::with_capacity(shards);
        for k in 0..shards {
            let (tx, rx) = mpsc::channel();
            let mut gs = GlobalScheduler::new(
                policy,
                cost.clone(),
                block_tokens,
                ttl,
            );
            if let Some(reg) = reg {
                gs.attach_obs(reg, Some(k as u32));
                gs.set_route_timer(crate::util::clock::monotonic_secs);
            }
            let acks = Arc::clone(&acks);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("gs-shard-{k}"))
                    .spawn(move || worker_loop(k, rx, gs, acks))
                    .expect("spawn shard worker"),
            );
            senders.push(tx);
        }
        ShardWorkerPool {
            senders,
            handles,
            map: ShardMap::new(shards, block_tokens),
            epoch: 0,
            acks,
        }
    }

    pub fn shard_count(&self) -> usize {
        self.senders.len()
    }

    pub fn map(&self) -> &ShardMap {
        &self.map
    }

    /// A clonable submit handle: give each submitter thread its own
    /// clone (the channels are MPSC, cloning is cheap).
    pub fn submitter(&self) -> ShardSubmitter {
        ShardSubmitter {
            senders: self.senders.clone(),
            map: self.map,
        }
    }

    /// Apply one delta: prefix-keyed events go to their shard's FIFO
    /// (no fence, no wait — the write scales); membership and
    /// whole-view events are epoch-fenced broadcasts.
    pub fn apply(&mut self, ev: &DeltaEvent) {
        match self.map.route(ev) {
            ShardRoute::One(s) => {
                let _ = self.senders[s].send(ShardRequest::Delta(ev.clone()));
            }
            ShardRoute::All => self.broadcast(ev),
        }
    }

    /// Epoch-fenced broadcast: every worker applies `ev` — and
    /// everything enqueued to it beforehand — before this returns.
    pub fn broadcast(&mut self, ev: &DeltaEvent) {
        self.epoch += 1;
        let epoch = self.epoch;
        for tx in &self.senders {
            let _ = tx.send(ShardRequest::Delta(ev.clone()));
            let _ = tx.send(ShardRequest::Fence { epoch });
        }
        self.wait_epoch(epoch);
    }

    /// Barrier without an event: drains every worker's queue up to the
    /// fence. Bench harnesses use this to bound a timed delta batch.
    pub fn fence(&mut self) {
        self.epoch += 1;
        let epoch = self.epoch;
        for tx in &self.senders {
            let _ = tx.send(ShardRequest::Fence { epoch });
        }
        self.wait_epoch(epoch);
    }

    fn wait_epoch(&self, epoch: u64) {
        self.acks.wait(epoch);
    }

    /// Per-shard (request id, decision) logs in each worker's
    /// processing order (fences first so in-flight work is included).
    pub fn decision_logs(&mut self) -> Vec<Vec<(u64, Decision)>> {
        self.fence();
        let mut out = Vec::with_capacity(self.senders.len());
        for tx in &self.senders {
            let (rtx, rrx) = mpsc::channel();
            let _ = tx.send(ShardRequest::Collect { reply: rtx });
            out.push(rrx.recv().unwrap_or_default());
        }
        out
    }

    /// Stop every worker and join. Idempotent; also runs on drop.
    pub fn shutdown(&mut self) {
        for tx in &self.senders {
            let _ = tx.send(ShardRequest::Stop);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for ShardWorkerPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Clonable per-thread submit handle (see [`ShardWorkerPool::submitter`]).
#[derive(Clone)]
pub struct ShardSubmitter {
    senders: Vec<Sender<ShardRequest>>,
    map: ShardMap,
}

impl ShardSubmitter {
    /// Route one request: one channel send to the prompt's shard, then
    /// block for that worker's reply. `loads` is the full fleet load
    /// snapshot the decision should use (see [`LoadVec`]).
    pub fn route(
        &self,
        id: u64,
        prompt: &[u32],
        session: u64,
        now: f64,
        loads: &LoadVec,
    ) -> anyhow::Result<RouteOutcome> {
        let s = self.map.shard_of_tokens(prompt).unwrap_or(0);
        let (tx, rx) = mpsc::channel();
        self.senders[s]
            .send(ShardRequest::Route {
                id,
                prompt: prompt.to_vec(),
                session,
                now,
                loads: Arc::clone(loads),
                reply: tx,
            })
            .map_err(|_| anyhow::anyhow!("shard {s} worker stopped"))?;
        rx.recv()
            .map_err(|_| anyhow::anyhow!("shard {s} worker dropped reply"))?
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::prompt_tree::InstanceKind;
    use crate::util::proptest::proptest;

    const BT: usize = 4;

    fn toks(len: usize, seed: u32) -> Vec<u32> {
        (0..len as u32)
            .map(|i| i.wrapping_mul(13).wrapping_add(seed) % 5)
            .collect()
    }

    fn fleet_loads(n_inst: u32) -> LoadVec {
        Arc::new(
            (0..n_inst)
                .map(|i| {
                    (
                        InstanceId(i),
                        InstanceLoad {
                            queued_tokens: (i as usize * 97) % 1024,
                            ..Default::default()
                        },
                    )
                })
                .collect(),
        )
    }

    /// The single-threaded monolithic reference: same joins, same
    /// records in the same order, loads re-asserted before every route
    /// exactly as the workers do.
    fn reference_run(
        shards: usize,
        n_inst: u32,
        records: &[(InstanceId, Vec<u32>)],
        requests: &[(u64, Vec<u32>, u64)],
        loads: &LoadVec,
    ) -> Vec<(u64, Decision)> {
        let mut gs = GlobalScheduler::with_shards(
            PolicyKind::PromptTree,
            OperatorCostModel::paper_13b(),
            BT,
            0.0,
            shards,
        );
        for i in 0..n_inst {
            gs.trees.apply_delta(&DeltaEvent::Join {
                instance: InstanceId(i),
                kind: InstanceKind::PrefillOnly,
            });
        }
        for (inst, t) in records {
            gs.trees.apply_delta(&DeltaEvent::Record {
                instance: *inst,
                tokens: t.clone(),
                now: 1.0,
            });
        }
        requests
            .iter()
            .map(|(id, prompt, session)| {
                for &(inst, load) in loads.iter() {
                    gs.set_load(inst, load);
                }
                let out = gs.route(prompt, *session, 2.0).unwrap();
                (*id, out.decision)
            })
            .collect()
    }

    fn seeded_pool(
        shards: usize,
        n_inst: u32,
        records: &[(InstanceId, Vec<u32>)],
    ) -> ShardWorkerPool {
        let mut pool = ShardWorkerPool::new(
            shards,
            BT,
            0.0,
            PolicyKind::PromptTree,
            OperatorCostModel::paper_13b(),
        );
        for i in 0..n_inst {
            pool.apply(&DeltaEvent::Join {
                instance: InstanceId(i),
                kind: InstanceKind::PrefillOnly,
            });
        }
        for (inst, t) in records {
            pool.apply(&DeltaEvent::Record {
                instance: *inst,
                tokens: t.clone(),
                now: 1.0,
            });
        }
        pool.fence();
        pool
    }

    /// ISSUE 7 satellite: N submitter threads route a seeded workload
    /// through the per-shard workers; every (request, decision) pair —
    /// and each per-shard stream, compared in request order — must
    /// equal the single-threaded monolithic reference run.
    #[test]
    fn prop_cross_thread_determinism() {
        proptest(6, |g| {
            let shards = [1usize, 2, 4][g.usize(0, 2)];
            let threads = g.usize(2, 4);
            let n_inst = 6 + g.usize(0, 6) as u32;
            let records: Vec<(InstanceId, Vec<u32>)> = (0..g.usize(4, 16))
                .map(|r| {
                    (
                        InstanceId(r as u32 % n_inst),
                        toks(g.usize(1, 4) * BT, g.u64(0, 40) as u32),
                    )
                })
                .collect();
            let requests: Vec<(u64, Vec<u32>, u64)> = (0..g.usize(8, 40))
                .map(|i| {
                    (
                        i as u64,
                        toks(g.usize(1, 4) * BT, g.u64(0, 40) as u32),
                        g.u64(0, 1 << 20),
                    )
                })
                .collect();
            let loads = fleet_loads(n_inst);
            let expect =
                reference_run(shards, n_inst, &records, &requests, &loads);

            let mut pool = seeded_pool(shards, n_inst, &records);
            let mut got: Vec<(u64, Decision)> = std::thread::scope(|sc| {
                let mut joins = vec![];
                for t in 0..threads {
                    let sub = pool.submitter();
                    let requests = &requests;
                    let loads = &loads;
                    joins.push(sc.spawn(move || {
                        let mut out = vec![];
                        // Round-robin partition of the request stream.
                        for (id, prompt, session) in
                            requests.iter().skip(t).step_by(threads)
                        {
                            let o = sub
                                .route(*id, prompt, *session, 2.0, loads)
                                .unwrap();
                            out.push((*id, o.decision));
                        }
                        out
                    }));
                }
                joins
                    .into_iter()
                    .flat_map(|j| j.join().unwrap())
                    .collect()
            });
            got.sort_by_key(|&(id, _)| id);
            assert_eq!(got, expect, "S={shards} T={threads}");

            // Per-shard streams: every worker's log holds exactly its
            // shard's requests, and in request order each stream equals
            // the reference's shard-projected stream.
            let logs = pool.decision_logs();
            let map = *pool.map();
            for (s, mut log) in logs.into_iter().enumerate() {
                for &(id, _) in &log {
                    let prompt = &requests[id as usize].1;
                    assert_eq!(
                        map.shard_of_tokens(prompt).unwrap_or(0),
                        s,
                        "request {id} logged on the wrong shard"
                    );
                }
                log.sort_by_key(|&(id, _)| id);
                let expect_s: Vec<(u64, Decision)> = expect
                    .iter()
                    .filter(|(id, _)| {
                        map.shard_of_tokens(&requests[*id as usize].1)
                            .unwrap_or(0)
                            == s
                    })
                    .cloned()
                    .collect();
                assert_eq!(log, expect_s, "shard {s} stream diverged");
            }
        });
    }

    /// T=1 over the worker pool is decision-identical to the
    /// monolithic scheduler — the structural bit-identity claim.
    #[test]
    fn single_thread_mode_matches_monolithic() {
        let n_inst = 8;
        let records: Vec<(InstanceId, Vec<u32>)> = (0..12)
            .map(|r| (InstanceId(r % n_inst), toks(2 * BT, r * 31)))
            .collect();
        let requests: Vec<(u64, Vec<u32>, u64)> = (0..30)
            .map(|i| (i as u64, toks(3 * BT, i as u32 * 7), i as u64))
            .collect();
        let loads = fleet_loads(n_inst);
        let expect = reference_run(2, n_inst, &records, &requests, &loads);
        let pool = seeded_pool(2, n_inst, &records);
        let sub = pool.submitter();
        for (id, prompt, session) in &requests {
            let o = sub.route(*id, prompt, *session, 2.0, &loads).unwrap();
            assert_eq!(
                (*id, o.decision),
                expect[*id as usize],
                "request {id}"
            );
        }
    }

    /// Membership broadcasts are epoch-fenced: after `apply(Leave)`
    /// returns, no shard routes to the departed instance.
    #[test]
    fn epoch_fenced_membership_is_visible_on_every_shard() {
        let n_inst = 4;
        let mut pool = seeded_pool(4, n_inst, &[]);
        let loads = fleet_loads(n_inst);
        let sub = pool.submitter();
        // Make instance 3 the cache holder for prompts on every shard.
        let prompts: Vec<Vec<u32>> =
            (0..16).map(|i| toks(2 * BT, i * 11)).collect();
        for p in &prompts {
            pool.apply(&DeltaEvent::Record {
                instance: InstanceId(3),
                tokens: p.clone(),
                now: 1.0,
            });
        }
        pool.fence();
        for (i, p) in prompts.iter().enumerate() {
            let o = sub.route(i as u64, p, 0, 2.0, &loads).unwrap();
            assert_eq!(o.decision.instance, InstanceId(3));
        }
        pool.apply(&DeltaEvent::Leave {
            instance: InstanceId(3),
        });
        // The broadcast has been fenced: every shard must already have
        // dropped instance 3 from its registry.
        for (i, p) in prompts.iter().enumerate() {
            let o = sub.route(100 + i as u64, p, 0, 3.0, &loads).unwrap();
            assert_ne!(o.decision.instance, InstanceId(3));
        }
        pool.shutdown();
    }
}
