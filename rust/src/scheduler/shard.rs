//! Prefix-range sharding of the fused prompt tree (ISSUE 5 tentpole).
//!
//! PR 4 replicated the global prompt tree, but every replica still
//! applies every delta: N replicas buy read throughput and durability
//! while *write* throughput stays at 1×. This module partitions the
//! fused tree over the **first token-block fingerprint range** into S
//! shards — the same cluster-scale move Mooncake's KVCache-centric
//! conductor and Infinite-LLM's distributed KV manager make (PAPERS.md):
//! no single node absorbs the whole fleet's metadata update stream.
//!
//! Why the *first* block: radix-tree prefix chains are rooted at block
//! 0, so every prefix of a prompt shares its first token-block — and
//! therefore its shard. A route walks exactly one shard's tree and
//! merges nothing; a `Record`/`Handoff`/`Expire` delta lands in exactly
//! one shard's log, so delta application and log append parallelize
//! S-ways. Only membership events (`Join`/`Leave`/`SetDraining`) and
//! whole-view expiries (a sub-block prefix, which `release_prefix`
//! treats as "clear everything") fan out to every shard.
//!
//! [`ShardedPromptTrees`] is the serving-side wrapper the
//! [`crate::scheduler::router::GlobalScheduler`] holds: S independent
//! [`FusedPromptTree`]s behind the single-tree surface, with S = 1
//! delegating straight through (bit-identical to the unsharded path —
//! the differential proptest below pins S ∈ {1, 2, 4} against both the
//! unsharded fused tree and the per-instance reference). The
//! replication side — one `ReplicaGroup`/`DeltaTransport` per shard —
//! lives in [`crate::replica::sharded`] and `server/replica.rs`.

use crate::elastic::delta::DeltaEvent;
use crate::mempool::index::block_fingerprint;
use crate::mempool::InstanceId;
use crate::scheduler::fused_tree::{FusedPromptTree, OwnedPrefix};
use crate::scheduler::prompt_tree::InstanceKind;
use crate::util::rng::splitmix64;

/// Default keyed-salt for first-block shard routing (PR 5 follow-up:
/// per-shard rebalancing, the cheap half). Raw `block_fingerprint`
/// values are well-spread for *random* blocks but workloads are not
/// random — a fleet-wide system prompt gives every request the same
/// block 0, and templated prompt families can cluster a fingerprint
/// *range* onto one shard. Mixing the fingerprint with a fixed key
/// through splitmix64 before range-partitioning decorrelates the shard
/// from any structure in the raw fingerprint while keeping the map
/// deterministic and identical across every `ShardMap::new` user
/// (serving trees, replication, replica groups must agree). Zero is
/// the "unsalted" sentinel ([`ShardMap::unsalted`]).
pub const DEFAULT_SHARD_SALT: u64 = 0xD6E8_FEB8_6659_FD93;

/// Where one delta (or read) goes in a sharded tree.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardRoute {
    /// Prefix-keyed: exactly one shard owns the whole prefix chain.
    One(usize),
    /// Membership / whole-view events: every shard applies it.
    All,
}

/// Maps a first token-block fingerprint onto one of S contiguous
/// fingerprint ranges. Range (not residue) partitioning: shard
/// `i` owns fingerprints in `[i·2^64/S, (i+1)·2^64/S)`, computed
/// without division as `(fp · S) >> 64`.
#[derive(Clone, Copy, Debug)]
pub struct ShardMap {
    shards: usize,
    block_tokens: usize,
    /// Mirrors the trees' fingerprint mask so forced-collision tests
    /// shard exactly the way the trees chain.
    fp_mask: u64,
    /// Keyed-salt mixed into the first-block fingerprint before range
    /// partitioning ([`DEFAULT_SHARD_SALT`]); 0 = unsalted.
    salt: u64,
}

impl ShardMap {
    pub fn new(shards: usize, block_tokens: usize) -> Self {
        Self::with_salt(shards, block_tokens, DEFAULT_SHARD_SALT)
    }

    /// The pre-salt layout: shards are raw fingerprint ranges. Kept
    /// reachable for differential proptests that reason about raw
    /// ranges (and for [`Self::set_fingerprint_mask`] users).
    pub fn unsalted(shards: usize, block_tokens: usize) -> Self {
        Self::with_salt(shards, block_tokens, 0)
    }

    fn with_salt(shards: usize, block_tokens: usize, salt: u64) -> Self {
        assert!(shards >= 1, "at least one shard");
        assert!(block_tokens > 0);
        ShardMap {
            shards,
            block_tokens,
            fp_mask: u64::MAX,
            salt,
        }
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    /// Test hook mirroring [`FusedPromptTree::set_fingerprint_mask`].
    /// Note a low-bit mask (e.g. `0xF`) collapses every fingerprint
    /// into shard 0's range; use a high-bit mask (`0xF << 60`) to force
    /// collisions *and* spread across shards. Also clears the salt:
    /// forced-collision tests reason about *raw* masked fingerprint
    /// ranges, and salting a masked fingerprint would re-spread exactly
    /// the collapse the mask is there to force.
    #[doc(hidden)]
    pub fn set_fingerprint_mask(&mut self, mask: u64) {
        self.fp_mask = mask;
        self.salt = 0;
    }

    /// Shard owning fingerprint `fp`.
    pub fn shard_of_fp(&self, fp: u64) -> usize {
        ((fp as u128 * self.shards as u128) >> 64) as usize
    }

    /// Keyed spread of a first-block fingerprint (identity when
    /// unsalted).
    #[inline]
    fn spread(&self, fp: u64) -> u64 {
        if self.salt == 0 {
            fp
        } else {
            let mut x = fp ^ self.salt;
            splitmix64(&mut x)
        }
    }

    /// Shard owning a token sequence (by its first full block, salted);
    /// `None` when the sequence is shorter than one block.
    pub fn shard_of_tokens(&self, tokens: &[u32]) -> Option<usize> {
        if tokens.len() < self.block_tokens {
            return None;
        }
        let fp =
            block_fingerprint(&tokens[..self.block_tokens]) & self.fp_mask;
        Some(self.shard_of_fp(self.spread(fp)))
    }

    /// Where one delta event must be applied (and logged).
    pub fn route(&self, ev: &DeltaEvent) -> ShardRoute {
        match ev {
            DeltaEvent::Join { .. }
            | DeltaEvent::Leave { .. }
            | DeltaEvent::SetDraining { .. } => ShardRoute::All,
            DeltaEvent::Record { tokens, .. }
            | DeltaEvent::Handoff { tokens, .. } => {
                // Sub-block payloads are no-ops in any tree; pin them to
                // shard 0 so they are logged (and no-op) exactly once.
                ShardRoute::One(self.shard_of_tokens(tokens).unwrap_or(0))
            }
            DeltaEvent::Expire { prefix, .. } => {
                match self.shard_of_tokens(prefix) {
                    Some(s) => ShardRoute::One(s),
                    // Less than one full block means "release the whole
                    // view" (`release_prefix` block-truncates to
                    // empty): every shard must clear its slice.
                    None => ShardRoute::All,
                }
            }
        }
    }
}

/// S independent [`FusedPromptTree`]s behind the single-tree surface
/// (see module docs). Every shard carries the full instance registry —
/// membership fans out — so any shard can answer registry reads and a
/// one-shard match still emits every routable instance.
pub struct ShardedPromptTrees {
    shards: Vec<FusedPromptTree>,
    map: ShardMap,
    /// Shard of the last [`Self::walk`]/match (split-phase reads).
    walked: usize,
    /// Bumped on every membership mutation (add/remove/drain toggle or
    /// a shard-tree swap); the router's load book resyncs when it
    /// changes.
    membership_gen: u64,
}

impl ShardedPromptTrees {
    /// Single-shard tree — bit-identical to an unsharded
    /// [`FusedPromptTree`] (every call delegates to shard 0).
    pub fn new(block_tokens: usize, ttl: f64) -> Self {
        Self::with_shards(block_tokens, ttl, 1)
    }

    pub fn with_shards(block_tokens: usize, ttl: f64, shards: usize)
                       -> Self {
        assert!(shards >= 1, "at least one shard");
        ShardedPromptTrees {
            shards: (0..shards)
                .map(|_| FusedPromptTree::new(block_tokens, ttl))
                .collect(),
            map: ShardMap::new(shards, block_tokens),
            walked: 0,
            membership_gen: 0,
        }
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    pub fn map(&self) -> &ShardMap {
        &self.map
    }

    pub fn block_tokens(&self) -> usize {
        self.map.block_tokens
    }

    /// Direct access to one shard's tree (snapshots, diagnostics).
    pub fn shard(&self, s: usize) -> &FusedPromptTree {
        &self.shards[s]
    }

    pub fn shard_mut(&mut self, s: usize) -> &mut FusedPromptTree {
        &mut self.shards[s]
    }

    /// Replace one shard's tree wholesale — the promotion landing path
    /// (a restored replica snapshot + replayed log suffix takes over
    /// that shard's slice of the fleet state).
    pub fn set_shard_tree(&mut self, s: usize, tree: FusedPromptTree) {
        assert_eq!(
            tree.block_tokens(),
            self.map.block_tokens,
            "shard tree geometry mismatch"
        );
        self.shards[s] = tree;
        self.membership_gen += 1;
    }

    /// Test hook: force fingerprint collisions in every shard *and* the
    /// shard map (so sharding follows the same collapsed fingerprints).
    #[doc(hidden)]
    pub fn set_fingerprint_mask(&mut self, mask: u64) {
        self.map.set_fingerprint_mask(mask);
        for t in &mut self.shards {
            t.set_fingerprint_mask(mask);
        }
    }

    /// Monotone counter of membership mutations (see field docs).
    pub fn membership_gen(&self) -> u64 {
        self.membership_gen
    }

    // ------------------------------------------------------------------
    // Registry (fanned to every shard; reads served by shard 0)
    // ------------------------------------------------------------------

    pub fn add_instance(&mut self, id: InstanceId, kind: InstanceKind) {
        for t in &mut self.shards {
            t.add_instance(id, kind);
        }
        self.membership_gen += 1;
    }

    pub fn remove_instance(&mut self, id: InstanceId) {
        for t in &mut self.shards {
            t.remove_instance(id);
        }
        self.membership_gen += 1;
    }

    pub fn set_draining(&mut self, id: InstanceId, draining: bool) {
        for t in &mut self.shards {
            t.set_draining(id, draining);
        }
        self.membership_gen += 1;
    }

    pub fn is_draining(&self, id: InstanceId) -> bool {
        self.shards[0].is_draining(id)
    }

    pub fn instances(
        &self,
    ) -> impl Iterator<Item = (InstanceId, InstanceKind)> + '_ {
        self.shards[0].instances()
    }

    pub fn instance_count(&self) -> usize {
        self.shards[0].instance_count()
    }

    pub fn kind_of(&self, id: InstanceId) -> Option<InstanceKind> {
        self.shards[0].kind_of(id)
    }

    pub fn is_route_candidate(&self, id: InstanceId) -> bool {
        self.shards[0].is_route_candidate(id)
    }

    pub fn routable_count(&self) -> usize {
        self.shards[0].routable_count()
    }

    /// Token-blocks believed cached on `id`, summed over shards.
    pub fn cached_blocks(&self, id: InstanceId) -> usize {
        self.shards.iter().map(|t| t.cached_blocks(id)).sum()
    }

    /// Live node count across shards (diagnostics).
    pub fn node_count(&self) -> usize {
        self.shards.iter().map(|t| t.node_count()).sum()
    }

    // ------------------------------------------------------------------
    // Writes (routed by first-block fingerprint)
    // ------------------------------------------------------------------

    pub fn record(&mut self, instance: InstanceId, tokens: &[u32],
                  now: f64) {
        // Sub-block records are no-ops everywhere (block truncation).
        if let Some(s) = self.map.shard_of_tokens(tokens) {
            self.shards[s].record(instance, tokens, now);
        }
    }

    pub fn release_prefix(&mut self, id: InstanceId, prefix: &[u32]) {
        match self.map.shard_of_tokens(prefix) {
            Some(s) => self.shards[s].release_prefix(id, prefix),
            // Whole-view release: every shard clears its slice.
            None => {
                for t in &mut self.shards {
                    t.release_prefix(id, prefix);
                }
            }
        }
    }

    /// Apply one ownership delta, routed to its shard (membership and
    /// whole-view expiries fan out) — the single write entry point, and
    /// exactly the per-shard split `gs_apply` logs by.
    pub fn apply_delta(&mut self, ev: &DeltaEvent) {
        if matches!(
            ev,
            DeltaEvent::Join { .. }
                | DeltaEvent::Leave { .. }
                | DeltaEvent::SetDraining { .. }
        ) {
            self.membership_gen += 1;
        }
        match self.map.route(ev) {
            ShardRoute::One(s) => self.shards[s].apply_delta(ev),
            ShardRoute::All => {
                for t in &mut self.shards {
                    t.apply_delta(ev);
                }
            }
        }
    }

    /// Returns total owner pairs expired across all shards (the
    /// `sched.expired_pairs` metric feed).
    pub fn expire(&mut self, now: f64) -> usize {
        self.shards.iter_mut().map(|t| t.expire(now)).sum()
    }

    // ------------------------------------------------------------------
    // Reads (one-shard walks)
    // ------------------------------------------------------------------

    #[inline]
    fn read_shard(&self, tokens: &[u32]) -> usize {
        // A prompt shorter than one block matches nothing anywhere;
        // shard 0 still emits the (all-zero) routable fleet.
        self.map.shard_of_tokens(tokens).unwrap_or(0)
    }

    pub fn match_into(
        &mut self,
        tokens: &[u32],
        out: &mut Vec<(InstanceId, usize)>,
    ) {
        let s = self.read_shard(tokens);
        self.walked = s;
        self.shards[s].match_into(tokens, out);
    }

    /// Split-phase walk (see [`FusedPromptTree::walk`]): one shard's
    /// tree is walked; [`Self::walked_len`]/[`Self::emit_walked`] read
    /// that shard until the next walk.
    pub fn walk(&mut self, tokens: &[u32]) {
        let s = self.read_shard(tokens);
        self.walked = s;
        self.shards[s].walk(tokens);
    }

    pub fn walked_len(&self, id: InstanceId) -> usize {
        self.shards[self.walked].walked_len(id)
    }

    pub fn emit_walked(
        &self,
        out: &mut Vec<(InstanceId, usize)>,
        cold_sorted: &[InstanceId],
    ) {
        self.shards[self.walked].emit_walked(out, cold_sorted);
    }

    pub fn match_one(&self, id: InstanceId, tokens: &[u32]) -> usize {
        self.shards[self.read_shard(tokens)].match_one(id, tokens)
    }

    /// The maximal prefixes `id` is believed to cache, across all
    /// shards, token-sorted (the same determinism contract as the
    /// unsharded [`FusedPromptTree::owned_paths`]).
    pub fn owned_paths(&self, id: InstanceId) -> Vec<OwnedPrefix> {
        let mut out: Vec<OwnedPrefix> = self
            .shards
            .iter()
            .flat_map(|t| t.owned_paths(id))
            .collect();
        out.sort_by(|a, b| a.tokens.cmp(&b.tokens));
        out
    }

    /// Per-shard counter invariants plus the cross-shard registry
    /// agreement the fan-out guarantees.
    #[doc(hidden)]
    pub fn debug_check_counters(&self) {
        for t in &self.shards {
            t.debug_check_counters();
        }
        let r0: Vec<_> = self.shards[0].instances().collect();
        for (s, t) in self.shards.iter().enumerate().skip(1) {
            assert_eq!(
                r0,
                t.instances().collect::<Vec<_>>(),
                "shard {s} registry diverged"
            );
            for &(id, _) in &r0 {
                assert_eq!(
                    self.shards[0].is_draining(id),
                    t.is_draining(id),
                    "shard {s} drain flag diverged for {id}"
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::policy::{decide, Candidate, PolicyKind};
    use crate::scheduler::prompt_tree::GlobalPromptTrees;
    use crate::scheduler::prompt_tree_ref::RefGlobalPromptTrees;
    use crate::util::proptest::proptest;

    const BT: usize = 4;

    fn toks(n: usize, seed: u32) -> Vec<u32> {
        (0..n as u32).map(|i| i * 3 + seed).collect()
    }

    #[test]
    fn range_partition_covers_all_shards_and_respects_prefixes() {
        let map = ShardMap::new(4, BT);
        assert_eq!(map.shard_of_fp(0), 0);
        assert_eq!(map.shard_of_fp(u64::MAX), 3);
        assert_eq!(map.shard_of_fp(u64::MAX / 2 + 1), 2);
        // Every prefix of a prompt maps to the same shard (they share
        // block 0), and long token streams spread across shards.
        let mut seen = [false; 4];
        for seed in 0..64 {
            let t = toks(4 * BT, seed * 97);
            let s = map.shard_of_tokens(&t).unwrap();
            for blocks in 1..=4 {
                assert_eq!(
                    map.shard_of_tokens(&t[..blocks * BT]),
                    Some(s),
                    "prefix changed shard"
                );
            }
            seen[s] = true;
        }
        assert!(seen.iter().all(|&s| s), "64 prompts must hit all 4 shards");
        // Sub-block sequences have no shard.
        assert_eq!(map.shard_of_tokens(&toks(BT - 1, 0)), None);
        assert_eq!(map.shard_of_tokens(&[]), None);
        // One shard: everything is shard 0.
        let one = ShardMap::new(1, BT);
        assert_eq!(one.shard_of_tokens(&toks(BT, 5)), Some(0));
    }

    #[test]
    fn salted_map_spreads_and_keeps_the_contracts() {
        let salted = ShardMap::new(4, BT);
        let unsalted = ShardMap::unsalted(4, BT);
        // Prefix-shard consistency survives salting (prefixes share
        // block 0), and the salted layout actually differs from the raw
        // ranges for some prompts (otherwise the salt does nothing).
        let mut differs = false;
        for seed in 0..64 {
            let t = toks(3 * BT, seed * 57 + 1);
            let s = salted.shard_of_tokens(&t).unwrap();
            for blocks in 1..=3 {
                assert_eq!(
                    salted.shard_of_tokens(&t[..blocks * BT]),
                    Some(s),
                    "salted prefix changed shard"
                );
            }
            differs |= unsalted.shard_of_tokens(&t) != Some(s);
        }
        assert!(differs, "salt must permute the raw-range layout");
        // Structured near-identical first blocks (templated prompts:
        // one varying token) spread under the salt — no shard may take
        // a super-majority of 256 distinct blocks.
        let mut counts = [0usize; 4];
        for i in 0..256u32 {
            let mut t = vec![7u32; BT];
            t[0] = i;
            counts[salted.shard_of_tokens(&t).unwrap()] += 1;
        }
        assert!(
            counts.iter().all(|&c| c > 0 && c < 160),
            "salted skew: {counts:?}"
        );
        // S=1 routes everything to shard 0 regardless of salt; masked
        // maps drop the salt so a low-bit mask still collapses to
        // shard 0 (the forced-collision contract).
        assert_eq!(ShardMap::new(1, BT).shard_of_tokens(&toks(BT, 5)),
                   Some(0));
        let mut masked = ShardMap::new(4, BT);
        masked.set_fingerprint_mask(0xF);
        for seed in 0..16 {
            assert_eq!(masked.shard_of_tokens(&toks(BT, seed)), Some(0));
        }
    }

    #[test]
    fn delta_routing_membership_fans_prefixes_pin() {
        let map = ShardMap::new(4, BT);
        let t = toks(2 * BT, 9);
        let s = map.shard_of_tokens(&t).unwrap();
        assert_eq!(
            map.route(&DeltaEvent::Record {
                instance: InstanceId(0),
                tokens: t.clone(),
                now: 1.0
            }),
            ShardRoute::One(s)
        );
        assert_eq!(
            map.route(&DeltaEvent::Handoff {
                from: InstanceId(0),
                to: InstanceId(1),
                tokens: t.clone(),
                now: 1.0
            }),
            ShardRoute::One(s)
        );
        assert_eq!(
            map.route(&DeltaEvent::Expire {
                instance: InstanceId(0),
                prefix: t.clone()
            }),
            ShardRoute::One(s)
        );
        // Whole-view expiry (sub-block prefix) hits every shard.
        assert_eq!(
            map.route(&DeltaEvent::Expire {
                instance: InstanceId(0),
                prefix: vec![]
            }),
            ShardRoute::All
        );
        assert_eq!(
            map.route(&DeltaEvent::Expire {
                instance: InstanceId(0),
                prefix: vec![1, 2]
            }),
            ShardRoute::All
        );
        for ev in [
            DeltaEvent::Join {
                instance: InstanceId(0),
                kind: InstanceKind::PrefillOnly,
            },
            DeltaEvent::Leave {
                instance: InstanceId(0),
            },
            DeltaEvent::SetDraining {
                instance: InstanceId(0),
                draining: true,
            },
        ] {
            assert_eq!(map.route(&ev), ShardRoute::All);
        }
    }

    #[test]
    fn records_land_in_one_shard_membership_in_all() {
        let mut g = ShardedPromptTrees::with_shards(BT, 0.0, 4);
        g.add_instance(InstanceId(0), InstanceKind::PrefillOnly);
        let t = toks(3 * BT, 7);
        let s = g.map().shard_of_tokens(&t).unwrap();
        g.record(InstanceId(0), &t, 1.0);
        for i in 0..4 {
            assert_eq!(g.shard(i).instance_count(), 1);
            assert_eq!(
                g.shard(i).node_count() > 0,
                i == s,
                "record leaked outside its shard"
            );
        }
        assert_eq!(g.match_one(InstanceId(0), &t), 3 * BT);
        assert_eq!(g.cached_blocks(InstanceId(0)), 3);
        g.debug_check_counters();
    }

    #[test]
    fn whole_view_release_clears_every_shard() {
        let mut g = ShardedPromptTrees::with_shards(BT, 0.0, 4);
        g.add_instance(InstanceId(0), InstanceKind::PrefillOnly);
        for seed in 0..16 {
            g.record(InstanceId(0), &toks(2 * BT, seed * 131), 1.0);
        }
        assert!(g.cached_blocks(InstanceId(0)) > 0);
        g.apply_delta(&DeltaEvent::Expire {
            instance: InstanceId(0),
            prefix: vec![],
        });
        assert_eq!(g.cached_blocks(InstanceId(0)), 0);
        assert_eq!(g.node_count(), 0);
        g.debug_check_counters();
    }

    #[test]
    fn membership_gen_tracks_mutations() {
        let mut g = ShardedPromptTrees::with_shards(BT, 0.0, 2);
        let g0 = g.membership_gen();
        g.add_instance(InstanceId(0), InstanceKind::PrefillOnly);
        assert!(g.membership_gen() > g0);
        let g1 = g.membership_gen();
        g.record(InstanceId(0), &toks(BT, 1), 1.0); // data, not membership
        assert_eq!(g.membership_gen(), g1);
        g.set_draining(InstanceId(0), true);
        assert!(g.membership_gen() > g1);
    }

    /// ISSUE 5 acceptance: shard counts {1, 2, 4} (fingerprint collision
    /// masks included) against BOTH the unsharded fused tree (bit-level
    /// behavior pin — S=1 must be identical, S>1 semantics-identical)
    /// and the per-instance reference trees, over the full delta
    /// interleaving of the existing differential property.
    #[test]
    fn prop_sharded_matches_unsharded_and_reference() {
        // High-bit masks force fingerprint collisions AND still spread
        // across the shard ranges (a low-bit mask would collapse every
        // fingerprint into shard 0 — also covered, via `0xF`).
        for (shards, mask) in [
            (1, u64::MAX),
            (2, u64::MAX),
            (4, u64::MAX),
            (4, 0xFu64 << 60),
            (2, 0xF),
        ] {
            proptest(10, move |g| {
                let ttl = 10.0;
                let mut shd = ShardedPromptTrees::with_shards(BT, ttl,
                                                              shards);
                let mut fused = GlobalPromptTrees::new(BT, ttl);
                // Masked runs exercise the unsalted raw-range layout
                // (set_fingerprint_mask clears the salt); the
                // full-fingerprint runs keep the default salted map, so
                // both layouts are pinned against the reference.
                if mask != u64::MAX {
                    shd.set_fingerprint_mask(mask);
                    fused.set_fingerprint_mask(mask);
                }
                let mut refr = RefGlobalPromptTrees::new(BT, ttl);
                let n_inst = 8 + g.usize(0, 8) as u32;
                for i in 0..n_inst {
                    let kind = match i % 4 {
                        0 => InstanceKind::DecodeOnly,
                        _ => InstanceKind::PrefillOnly,
                    };
                    let id = InstanceId(i);
                    shd.add_instance(id, kind);
                    fused.add_instance(id, kind);
                    refr.add_instance(id, kind);
                }
                let mut now = 0.0;
                for _ in 0..g.usize(10, 40) {
                    now += g.f64(0.1, 3.0);
                    let len = g.usize(0, 5) * BT + g.usize(0, BT - 1);
                    let t = g.vec_u32(len, 0, 3);
                    let inst = InstanceId(g.u64(0, (n_inst - 1) as u64)
                                          as u32);
                    let ev = match g.usize(0, 8) {
                        0 | 1 | 2 => DeltaEvent::Record {
                            instance: inst,
                            tokens: t.clone(),
                            now,
                        },
                        3 => DeltaEvent::Expire {
                            instance: inst,
                            prefix: t.clone(),
                        },
                        4 => DeltaEvent::Handoff {
                            from: inst,
                            to: InstanceId((inst.0 + 1) % n_inst),
                            tokens: t.clone(),
                            now,
                        },
                        5 => DeltaEvent::SetDraining {
                            instance: inst,
                            draining: g.bool(),
                        },
                        // Membership churn: leave / rejoin fans to
                        // every shard.
                        6 => match shd.kind_of(inst) {
                            Some(_) => DeltaEvent::Leave { instance: inst },
                            None => DeltaEvent::Join {
                                instance: inst,
                                kind: InstanceKind::PrefillOnly,
                            },
                        },
                        _ => {
                            shd.expire(now);
                            fused.expire(now);
                            refr.expire(now);
                            continue;
                        }
                    };
                    shd.apply_delta(&ev);
                    fused.apply_delta(&ev);
                    refr.apply_delta(&ev);
                    // Probe: full matched vectors + a policy decision.
                    let probe = g.vec_u32(g.usize(0, 4) * BT, 0, 3);
                    let mut got_s = vec![];
                    shd.match_into(&probe, &mut got_s);
                    let mut got_f = vec![];
                    fused.match_into(&probe, &mut got_f);
                    let expect = refr.match_all(&probe);
                    assert_eq!(got_s, got_f, "sharded vs fused (S={shards})");
                    assert_eq!(got_s, expect, "sharded vs reference");
                    if !got_s.is_empty() {
                        let cands = |m: &[(InstanceId, usize)]| {
                            m.iter()
                                .map(|&(id, matched)| Candidate {
                                    instance: id,
                                    queued_tokens: (id.0 as usize * 37)
                                        % 256,
                                    queued_cached_ratio: 0.0,
                                    matched_tokens: matched,
                                    pressure: 0.0,
                                })
                                .collect::<Vec<_>>()
                        };
                        for policy in [
                            PolicyKind::LeastLoad,
                            PolicyKind::PromptTree,
                        ] {
                            assert_eq!(
                                decide(policy, &cands(&got_s), probe.len(),
                                       3, |x, y| x as f64 * (1.0 - y) + 1.0),
                                decide(policy, &cands(&expect), probe.len(),
                                       3, |x, y| x as f64 * (1.0 - y) + 1.0),
                                "decision diverged (S={shards})"
                            );
                        }
                    }
                    for i in 0..n_inst {
                        let id = InstanceId(i);
                        assert_eq!(
                            shd.cached_blocks(id),
                            refr.cached_blocks(id),
                            "cached_blocks({id}) S={shards}"
                        );
                        assert_eq!(
                            shd.match_one(id, &probe),
                            fused.match_one(id, &probe),
                            "match_one({id}) S={shards}"
                        );
                    }
                    shd.debug_check_counters();
                }
                // owned_paths determinism across the shard split.
                for i in 0..n_inst {
                    let id = InstanceId(i);
                    assert_eq!(
                        shd.owned_paths(id),
                        fused.owned_paths(id),
                        "owned_paths({id}) S={shards}"
                    );
                }
            });
        }
    }
}
