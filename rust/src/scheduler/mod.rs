//! Global scheduler (paper §6): global prompt trees, routing policies,
//! and the context-caching cost model (§5.3).

pub mod cost_model;
pub mod data_plane;
pub mod fused_tree;
pub mod policy;
pub mod prompt_tree;
pub mod prompt_tree_ref;
pub mod router;
pub mod shard;

pub use policy::PolicyKind;
