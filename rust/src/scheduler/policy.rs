//! Global request-scheduling policies (paper Table 6).
//!
//! * **LeastLoad** — pick the least-loaded instance; locality-blind.
//! * **SessionId** — hash the session onto an instance; intra-session
//!   caching only.
//! * **PromptTree** — the paper's contribution: match the prompt against
//!   per-instance global prompt trees and pick via the cost model
//!   (Eq. 1), exploiting inter-session sharing.

use crate::mempool::InstanceId;
use crate::scheduler::cost_model::pressure_discount;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PolicyKind {
    LeastLoad,
    SessionId,
    PromptTree,
}

impl PolicyKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "least_load" => Some(PolicyKind::LeastLoad),
            "session_id" | "session" => Some(PolicyKind::SessionId),
            "prompt_tree" => Some(PolicyKind::PromptTree),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            PolicyKind::LeastLoad => "least_load",
            PolicyKind::SessionId => "session_id",
            PolicyKind::PromptTree => "prompt_tree",
        }
    }
}

/// Load + cache view of one candidate instance, assembled by the router
/// into a reused scratch buffer (plain-old-data, hence `Copy`).
#[derive(Clone, Copy, Debug)]
pub struct Candidate {
    pub instance: InstanceId,
    /// Sum of pending prompt tokens (the queueing term of Eq. 1).
    pub queued_tokens: usize,
    /// Mean cached ratio of the queued work (for exec() of the queue).
    pub queued_cached_ratio: f64,
    /// Matched prefix tokens for *this* prompt on this instance.
    pub matched_tokens: usize,
    /// Capacity pressure in [0, 1] (pool occupancy): instances near
    /// eviction churn get their matched length discounted (see
    /// [`pressure_discount`]) — they are worse cache holders *and*
    /// worse donors than the raw match suggests.
    pub pressure: f64,
}

/// Decision output: chosen instance plus (optionally) a donor holding a
/// longer prefix, for the Eq. 2 transfer-vs-recompute step.
#[derive(Clone, Debug, PartialEq)]
pub struct Decision {
    pub instance: InstanceId,
    pub matched_tokens: usize,
    /// Some((donor, donor_matched)) when another instance holds more.
    pub donor: Option<(InstanceId, usize)>,
}

/// Pick per policy. `exec` estimates prefill seconds for
/// (prompt_tokens, cached_ratio) — the cost model's exec(x, y).
pub fn decide<F: Fn(usize, f64) -> f64>(
    policy: PolicyKind,
    candidates: &[Candidate],
    prompt_tokens: usize,
    session_id: u64,
    exec: F,
) -> Decision {
    assert!(!candidates.is_empty());
    let chosen = match policy {
        PolicyKind::LeastLoad => candidates
            .iter()
            .min_by_key(|c| c.queued_tokens)
            .unwrap(),
        PolicyKind::SessionId => {
            let i = (session_id % candidates.len() as u64) as usize;
            &candidates[i]
        }
        PolicyKind::PromptTree => {
            // Eq. 1: argmin_p sum_queue exec(x', y') + exec(x, y_p),
            // with y_p discounted by capacity pressure (a near-full pool
            // may churn the matched prefix away before this request is
            // scheduled). Exact cost ties (e.g. a cold prompt over idle
            // instances) break by load, then by a session hash —
            // otherwise every cold request piles onto the first
            // instance and the tail suffers.
            let cost = |c: &Candidate| {
                exec(c.queued_tokens, c.queued_cached_ratio)
                    + exec(
                        prompt_tokens,
                        c.matched_tokens as f64
                            * pressure_discount(c.pressure)
                            / prompt_tokens.max(1) as f64,
                    )
            };
            candidates
                .iter()
                .min_by(|a, b| {
                    cost(a)
                        .partial_cmp(&cost(b))
                        .unwrap()
                        .then(a.queued_tokens.cmp(&b.queued_tokens))
                        .then_with(|| {
                            let h = |c: &Candidate| {
                                let mut s = session_id
                                    ^ ((c.instance.0 as u64) << 32);
                                crate::util::rng::splitmix64(&mut s)
                            };
                            h(a).cmp(&h(b))
                        })
                })
                .unwrap()
        }
    };
    // Donor: an instance holding strictly more of this prompt's prefix
    // — both nominally (the documented contract: a donor only makes
    // sense if it has tokens the chosen instance lacks) and after the
    // pressure discount (a churning donor's prefix may be gone by the
    // time Eq. 2's transfer starts). Ranked by discounted length.
    let eff = |c: &Candidate| {
        c.matched_tokens as f64 * pressure_discount(c.pressure)
    };
    let donor = candidates
        .iter()
        .filter(|c| c.instance != chosen.instance)
        .max_by(|a, b| {
            eff(a)
                .partial_cmp(&eff(b))
                .unwrap_or(std::cmp::Ordering::Equal)
        })
        .filter(|c| {
            c.matched_tokens > chosen.matched_tokens && eff(c) > eff(chosen)
        })
        .map(|c| (c.instance, c.matched_tokens));
    Decision {
        instance: chosen.instance,
        matched_tokens: chosen.matched_tokens,
        donor,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cand(id: u32, queued: usize, matched: usize) -> Candidate {
        Candidate {
            instance: InstanceId(id),
            queued_tokens: queued,
            queued_cached_ratio: 0.0,
            matched_tokens: matched,
            pressure: 0.0,
        }
    }

    /// Linear-ish exec toy model: cost ∝ uncached tokens.
    fn exec(x: usize, y: f64) -> f64 {
        x as f64 * (1.0 - y) + 1.0
    }

    #[test]
    fn parse_names() {
        for p in [
            PolicyKind::LeastLoad,
            PolicyKind::SessionId,
            PolicyKind::PromptTree,
        ] {
            assert_eq!(PolicyKind::parse(p.name()), Some(p));
        }
        assert_eq!(PolicyKind::parse("x"), None);
    }

    #[test]
    fn least_load_ignores_cache() {
        let cs = vec![cand(0, 100, 500), cand(1, 10, 0)];
        let d = decide(PolicyKind::LeastLoad, &cs, 512, 7, exec);
        assert_eq!(d.instance, InstanceId(1));
        // But the donor field still reports instance 0's longer prefix.
        assert_eq!(d.donor, Some((InstanceId(0), 500)));
    }

    #[test]
    fn session_id_is_sticky() {
        let cs = vec![cand(0, 0, 0), cand(1, 0, 0), cand(2, 0, 0)];
        let a = decide(PolicyKind::SessionId, &cs, 100, 5, exec);
        let b = decide(PolicyKind::SessionId, &cs, 100, 5, exec);
        assert_eq!(a.instance, b.instance);
        assert_eq!(a.instance, InstanceId(2)); // 5 % 3
    }

    #[test]
    fn prompt_tree_prefers_cache_hit() {
        let cs = vec![cand(0, 0, 0), cand(1, 0, 448)];
        let d = decide(PolicyKind::PromptTree, &cs, 512, 0, exec);
        assert_eq!(d.instance, InstanceId(1));
        assert_eq!(d.matched_tokens, 448);
        assert_eq!(d.donor, None);
    }

    #[test]
    fn prompt_tree_balances_queue_vs_cache() {
        // Instance 1 has the cache but a huge queue; 0 is idle.
        let mut c1 = cand(1, 100_000, 256);
        c1.queued_cached_ratio = 0.0;
        let cs = vec![cand(0, 0, 0), c1];
        let d = decide(PolicyKind::PromptTree, &cs, 512, 0, exec);
        assert_eq!(d.instance, InstanceId(0));
        // Donor points at the cache-rich instance for Eq. 2.
        assert_eq!(d.donor, Some((InstanceId(1), 256)));
    }

    #[test]
    fn no_donor_when_chosen_has_most() {
        let cs = vec![cand(0, 0, 512), cand(1, 0, 100)];
        let d = decide(PolicyKind::PromptTree, &cs, 512, 0, exec);
        assert_eq!(d.instance, InstanceId(0));
        assert_eq!(d.donor, None);
    }

    #[test]
    fn pressure_discounts_cache_holder() {
        // Both hold the same match; instance 0 is churning near
        // capacity, so Eq. 1 must prefer the calm instance 1.
        let mut hot = cand(0, 0, 448);
        hot.pressure = 1.0;
        let cs = vec![hot, cand(1, 0, 448)];
        let d = decide(PolicyKind::PromptTree, &cs, 512, 0, exec);
        assert_eq!(d.instance, InstanceId(1));
        // Below the churn knee the signal is silent: ties break exactly
        // as without pressure (load, then session hash).
        let mut calm = cand(0, 0, 448);
        calm.pressure = 0.5;
        let cs0 = vec![calm, cand(1, 0, 448)];
        let base = vec![cand(0, 0, 448), cand(1, 0, 448)];
        assert_eq!(
            decide(PolicyKind::PromptTree, &cs0, 512, 3, exec),
            decide(PolicyKind::PromptTree, &base, 512, 3, exec)
        );
    }

    #[test]
    fn donor_needs_strictly_more_raw_tokens_than_chosen() {
        // Chosen holds 448 raw (eff 224 under full pressure); the other
        // candidate's 300 raw is effectively "more" (eff 300) but holds
        // nothing the chosen instance lacks — no donor.
        let mut hot = cand(0, 0, 448);
        hot.pressure = 1.0;
        let busy = cand(1, 1_000_000, 300); // queue keeps it from winning
        let cs = vec![hot, busy];
        let d = decide(PolicyKind::PromptTree, &cs, 512, 0, exec);
        assert_eq!(d.instance, InstanceId(0));
        assert_eq!(d.donor, None);
    }

    #[test]
    fn pressured_donor_loses_to_calm_donor() {
        // Chosen is 0 (idle, no cache). Donor pick: instance 2 matches
        // slightly less than 1 but 1 churns at full pressure — the
        // effective length ranks 2 first.
        let mut churny = cand(1, 100_000, 500);
        churny.pressure = 1.0;
        let cs = vec![cand(0, 0, 0), churny, cand(2, 100_000, 400)];
        let d = decide(PolicyKind::PromptTree, &cs, 512, 0, exec);
        assert_eq!(d.instance, InstanceId(0));
        assert_eq!(d.donor, Some((InstanceId(2), 400)));
    }
}
