//! Reference global prompt trees — the seed's per-instance layout,
//! preserved as a differential-testing baseline and benchmark reference
//! for the fused tree ([`crate::scheduler::fused_tree`]).
//!
//! One [`RadixIndex`] per instance, walked **per instance** on every
//! match: O(instances × prompt_blocks) per route, which is exactly the
//! scaling the fused tree removes (`benches/fig15_scheduler.rs` sweeps
//! instance counts against this implementation).
//!
//! One deliberate semantic alignment with the fused tree: matching is
//! *read-only* ([`RadixIndex::match_len`]) and TTL staleness is driven
//! by insert recency alone. The seed bumped `last_access` on every
//! match, so merely *routing* a prompt kept its global-tree entries
//! alive — but the GS never learns whether the instance still holds the
//! data, so insert recency is the only honest signal (§6 Discussion).
//! Both implementations now share that rule, which is what makes the
//! differential property in this module exact, including expiry and
//! instance-removal interleavings.

use std::collections::BTreeMap;

use crate::elastic::delta::DeltaEvent;
use crate::mempool::{InstanceId, RadixIndex};
use crate::scheduler::prompt_tree::InstanceKind;

struct TreeEntry {
    kind: InstanceKind,
    tree: RadixIndex,
    /// Draining instances are excluded from `match_all` (mirrors the
    /// fused tree's route-mask exclusion) but stay matchable via
    /// `match_one`.
    draining: bool,
}

/// All per-instance global prompt trees, keyed by instance.
pub struct RefGlobalPromptTrees {
    trees: BTreeMap<InstanceId, TreeEntry>,
    block_tokens: usize,
    ttl: f64,
}

impl RefGlobalPromptTrees {
    pub fn new(block_tokens: usize, ttl: f64) -> Self {
        RefGlobalPromptTrees {
            trees: BTreeMap::new(),
            block_tokens,
            ttl,
        }
    }

    pub fn add_instance(&mut self, id: InstanceId, kind: InstanceKind) {
        self.trees.insert(
            id,
            TreeEntry {
                kind,
                tree: RadixIndex::new(self.block_tokens, self.ttl),
                draining: false,
            },
        );
    }

    /// Drop a failed/removed instance's tree (paper §4.4: membership
    /// change broadcast).
    pub fn remove_instance(&mut self, id: InstanceId) {
        self.trees.remove(&id);
    }

    pub fn kind_of(&self, id: InstanceId) -> Option<InstanceKind> {
        self.trees.get(&id).map(|e| e.kind)
    }

    pub fn instances(
        &self,
    ) -> impl Iterator<Item = (InstanceId, InstanceKind)> + '_ {
        self.trees.iter().map(|(&id, e)| (id, e.kind))
    }

    /// Record that `instance` now caches `tokens` (response path).
    pub fn record(&mut self, instance: InstanceId, tokens: &[u32], now: f64) {
        let Some(e) = self.trees.get_mut(&instance) else {
            return;
        };
        e.tree.insert_unaddressed(tokens, now);
    }

    /// Matched prefix length (tokens) on every routable (prefill-capable,
    /// non-draining) instance — one full tree walk *per instance* (the
    /// seed scheduling path).
    pub fn match_all(&self, tokens: &[u32]) -> Vec<(InstanceId, usize)> {
        self.trees
            .iter()
            .filter(|(_, e)| e.kind.runs_prefill() && !e.draining)
            .map(|(id, e)| (*id, e.tree.match_len(tokens)))
            .collect()
    }

    /// Routing visibility toggle (see the fused tree's `set_draining`).
    pub fn set_draining(&mut self, id: InstanceId, draining: bool) {
        if let Some(e) = self.trees.get_mut(&id) {
            e.draining = draining;
        }
    }

    pub fn is_draining(&self, id: InstanceId) -> bool {
        self.trees.get(&id).is_some_and(|e| e.draining)
    }

    /// `id` no longer caches `prefix` nor any extension of it (the
    /// `DeltaEvent::Expire` primitive): per-instance trees make this a
    /// straight [`RadixIndex::prune_at`].
    pub fn release_prefix(&mut self, id: InstanceId, prefix: &[u32]) {
        if let Some(e) = self.trees.get_mut(&id) {
            e.tree.prune_at(prefix);
        }
    }

    /// Apply one ownership delta event — the reference semantics the
    /// fused tree's `apply_delta` is pinned against differentially.
    pub fn apply_delta(&mut self, ev: &DeltaEvent) {
        match ev {
            DeltaEvent::Join { instance, kind } => {
                self.add_instance(*instance, *kind);
            }
            DeltaEvent::Leave { instance } => self.remove_instance(*instance),
            DeltaEvent::Record {
                instance,
                tokens,
                now,
            } => self.record(*instance, tokens, *now),
            DeltaEvent::Expire { instance, prefix } => {
                self.release_prefix(*instance, prefix);
            }
            DeltaEvent::Handoff {
                from,
                to,
                tokens,
                now,
            } => {
                // Mirror the fused tree: no sub-block handoffs, and an
                // unknown receiver must not retire the donor's claim.
                if tokens.len() < self.block_tokens
                    || !self.trees.contains_key(to)
                {
                    return;
                }
                self.record(*to, tokens, *now);
                self.release_prefix(*from, tokens);
            }
            DeltaEvent::SetDraining { instance, draining } => {
                self.set_draining(*instance, *draining);
            }
        }
    }

    /// Matched prefix on one specific instance.
    pub fn match_one(&self, id: InstanceId, tokens: &[u32]) -> usize {
        self.trees
            .get(&id)
            .map(|e| e.tree.match_len(tokens))
            .unwrap_or(0)
    }

    /// TTL housekeeping: full fixpoint scan over every tree (the cost
    /// the fused tree's expiry heap removes).
    pub fn expire(&mut self, now: f64) {
        for e in self.trees.values_mut() {
            e.tree.expire(now);
        }
    }

    /// Total cached token-blocks believed to exist per instance.
    pub fn cached_blocks(&self, id: InstanceId) -> usize {
        self.trees
            .get(&id)
            .map(|e| e.tree.total_token_blocks())
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::policy::{decide, Candidate, PolicyKind};
    use crate::scheduler::prompt_tree::GlobalPromptTrees;
    use crate::util::proptest::proptest;

    const BT: usize = 4;

    /// Deterministic synthetic load for policy-decision comparison.
    fn load_of(id: InstanceId) -> usize {
        ((id.0 as u64).wrapping_mul(2654435761) % 4096) as usize
    }

    /// Deterministic synthetic capacity pressure (some instances above
    /// the churn knee, some below).
    fn pressure_of(id: InstanceId) -> f64 {
        ((id.0 as u64).wrapping_mul(0x9E3779B97F4A7C15) % 1000) as f64
            / 1000.0
    }

    fn candidates(matches: &[(InstanceId, usize)]) -> Vec<Candidate> {
        matches
            .iter()
            .map(|&(id, matched)| Candidate {
                instance: id,
                queued_tokens: load_of(id),
                queued_cached_ratio: 0.0,
                matched_tokens: matched,
                pressure: pressure_of(id),
            })
            .collect()
    }

    fn exec(x: usize, y: f64) -> f64 {
        x as f64 * (1.0 - y) + 1.0
    }

    /// The ISSUE's differential property: random record / route /
    /// expire / remove-instance sequences — now interleaved with the
    /// elasticity deltas (prefix expiry, handoffs, drain toggles,
    /// leave/rejoin) — over ≥64 instances produce identical
    /// matched-prefix vectors, per-instance counters, and policy
    /// decisions on the fused tree and the per-instance reference —
    /// under the normal fingerprint and under a 4-bit mask that forces
    /// collision chaining in the fused tree.
    #[test]
    fn prop_fused_matches_reference_trees() {
        use crate::elastic::delta::DeltaEvent;
        for mask in [u64::MAX, 0xF] {
            proptest(20, move |g| {
                let ttl = 10.0;
                let mut fused = GlobalPromptTrees::new(BT, ttl);
                fused.set_fingerprint_mask(mask);
                let mut refr = RefGlobalPromptTrees::new(BT, ttl);
                let n_inst = 64 + g.usize(0, 8);
                let mut live: Vec<InstanceId> = vec![];
                let mut removed: Vec<InstanceId> = vec![];
                for i in 0..n_inst {
                    let id = InstanceId(i as u32);
                    let kind = match i % 5 {
                        0 => InstanceKind::DecodeOnly,
                        1 => InstanceKind::Colocated,
                        _ => InstanceKind::PrefillOnly,
                    };
                    fused.add_instance(id, kind);
                    refr.add_instance(id, kind);
                    live.push(id);
                }
                // Apply one delta to both implementations.
                let both = |fused: &mut GlobalPromptTrees,
                            refr: &mut RefGlobalPromptTrees,
                            ev: DeltaEvent| {
                    fused.apply_delta(&ev);
                    refr.apply_delta(&ev);
                };
                let mut now = 0.0;
                for _ in 0..g.usize(10, 50) {
                    now += g.f64(0.1, 4.0);
                    // Small alphabet: shared prefixes, splits, and (with
                    // the masked fingerprint) collision chains.
                    let len = g.usize(0, 6) * BT + g.usize(0, BT - 1);
                    let toks = g.vec_u32(len, 0, 3);
                    match g.usize(0, 12) {
                        0..=3 => {
                            if !live.is_empty() {
                                let id = *g.pick(&live);
                                fused.record(id, &toks, now);
                                refr.record(id, &toks, now);
                            }
                        }
                        4..=6 => {
                            let mut got = vec![];
                            fused.match_into(&toks, &mut got);
                            let expect = refr.match_all(&toks);
                            assert_eq!(got, expect, "matched vectors");
                            if !got.is_empty() {
                                let c1 = candidates(&got);
                                let c2 = candidates(&expect);
                                let sid = g.u64(0, 1 << 20);
                                for policy in [
                                    PolicyKind::LeastLoad,
                                    PolicyKind::SessionId,
                                    PolicyKind::PromptTree,
                                ] {
                                    let d1 = decide(
                                        policy, &c1, toks.len(), sid, exec,
                                    );
                                    let d2 = decide(
                                        policy, &c2, toks.len(), sid, exec,
                                    );
                                    assert_eq!(d1, d2, "policy decision");
                                }
                                // Capped emission (ISSUE 4 satellite):
                                // warm instances + a 4-cold sample
                                // ranked exactly as each load-monotone
                                // policy orders zero-match candidates
                                // must reproduce the decision the
                                // reference's FULL emission yields.
                                let mut capped = vec![];
                                let mut rank_pt = |id: InstanceId| {
                                    let mut s =
                                        sid ^ ((id.0 as u64) << 32);
                                    (
                                        exec(load_of(id), 0.0),
                                        load_of(id) as u64,
                                        crate::util::rng::splitmix64(
                                            &mut s,
                                        ),
                                    )
                                };
                                fused.match_into_capped(
                                    &toks,
                                    &mut capped,
                                    4,
                                    &mut rank_pt,
                                );
                                assert_eq!(
                                    decide(
                                        PolicyKind::PromptTree,
                                        &candidates(&capped),
                                        toks.len(),
                                        sid,
                                        exec,
                                    ),
                                    decide(
                                        PolicyKind::PromptTree,
                                        &c2,
                                        toks.len(),
                                        sid,
                                        exec,
                                    ),
                                    "capped prompt-tree decision"
                                );
                                let mut rank_ll = |id: InstanceId| {
                                    (load_of(id) as f64, id.0 as u64, 0u64)
                                };
                                fused.match_into_capped(
                                    &toks,
                                    &mut capped,
                                    4,
                                    &mut rank_ll,
                                );
                                assert_eq!(
                                    decide(
                                        PolicyKind::LeastLoad,
                                        &candidates(&capped),
                                        toks.len(),
                                        sid,
                                        exec,
                                    ),
                                    decide(
                                        PolicyKind::LeastLoad,
                                        &c2,
                                        toks.len(),
                                        sid,
                                        exec,
                                    ),
                                    "capped least-load decision"
                                );
                            }
                            if !live.is_empty() {
                                let id = *g.pick(&live);
                                assert_eq!(
                                    fused.match_one(id, &toks),
                                    refr.match_one(id, &toks),
                                    "match_one({id})"
                                );
                            }
                        }
                        7 => {
                            fused.expire(now);
                            refr.expire(now);
                        }
                        8 => {
                            // Leave / rejoin through the delta log (an
                            // instance returning after decommission is
                            // a fresh member).
                            if live.len() > 1 && g.bool() {
                                let i = g.usize(0, live.len() - 1);
                                let id = live.swap_remove(i);
                                both(
                                    &mut fused,
                                    &mut refr,
                                    DeltaEvent::Leave { instance: id },
                                );
                                removed.push(id);
                            } else if let Some(id) = removed.pop() {
                                both(&mut fused, &mut refr, DeltaEvent::Join {
                                    instance: id,
                                    kind: InstanceKind::PrefillOnly,
                                });
                                live.push(id);
                            }
                        }
                        9 => {
                            // Honest local-eviction report: a prefix and
                            // its extensions disappear from one view.
                            if !live.is_empty() {
                                let id = *g.pick(&live);
                                both(
                                    &mut fused,
                                    &mut refr,
                                    DeltaEvent::Expire {
                                        instance: id,
                                        prefix: toks.clone(),
                                    },
                                );
                            }
                        }
                        10 => {
                            // Live-migration handoff between two distinct
                            // instances (drain-time ownership re-point).
                            // Sometimes the receiver is a *removed* id —
                            // a late ack racing a failure sweep — which
                            // must leave the donor's claim intact.
                            if live.len() > 1 {
                                let i = g.usize(0, live.len() - 1);
                                let to = if !removed.is_empty() && g.bool() {
                                    *g.pick(&removed)
                                } else {
                                    let mut j = g.usize(0, live.len() - 1);
                                    if i == j {
                                        j = (j + 1) % live.len();
                                    }
                                    live[j]
                                };
                                both(
                                    &mut fused,
                                    &mut refr,
                                    DeltaEvent::Handoff {
                                        from: live[i],
                                        to,
                                        tokens: toks.clone(),
                                        now,
                                    },
                                );
                            }
                        }
                        11 => {
                            // Drain toggle: routing visibility only.
                            if !live.is_empty() {
                                let id = *g.pick(&live);
                                let draining = g.bool();
                                both(
                                    &mut fused,
                                    &mut refr,
                                    DeltaEvent::SetDraining {
                                        instance: id,
                                        draining,
                                    },
                                );
                                assert_eq!(
                                    fused.is_draining(id),
                                    refr.is_draining(id)
                                );
                            }
                        }
                        _ => {
                            for &id in &live {
                                assert_eq!(
                                    fused.cached_blocks(id),
                                    refr.cached_blocks(id),
                                    "cached_blocks({id})"
                                );
                            }
                        }
                    }
                    fused.debug_check_counters();
                }
            });
        }
    }
}
