//! End-to-end integration tests over the live server: real PJRT compute,
//! real fabric messages, all three instance roles. Self-skips when
//! `make artifacts` has not run.

use std::sync::Arc;
use std::time::Duration;

use memserve::config::Config;
use memserve::elastic::InstanceState;
use memserve::engine::{DisaggMilestone, SamplingParams};
use memserve::mempool::InstanceId;
use memserve::runtime::artifacts::artifacts_available;
use memserve::runtime::ModelRuntime;
use memserve::scheduler::prompt_tree::InstanceKind;
use memserve::server::{ServeCluster, ServeOptions};

use once_cell::sync::Lazy;

static RT: Lazy<Option<Arc<ModelRuntime>>> = Lazy::new(|| {
    if !artifacts_available("artifacts") {
        eprintln!("[skip] artifacts/ not built");
        return None;
    }
    Some(Arc::new(ModelRuntime::load("artifacts").unwrap()))
});

fn config(prefill: usize, decode: usize, colocated: usize, caching: bool)
          -> Config {
    let mut cfg = Config::default();
    cfg.cluster.prefill_instances = prefill;
    cfg.cluster.decode_instances = decode;
    cfg.cluster.colocated_instances = colocated;
    // Generous under parallel-test CPU contention; the failover test
    // overrides this locally.
    cfg.cluster.heartbeat_ms = 200.0;
    cfg.cluster.heartbeat_misses = 5;
    cfg.mempool.context_caching = caching;
    cfg.mempool.hbm_blocks = 256;
    cfg.mempool.dram_blocks = 256;
    cfg
}

fn start(cfg: Config, milestone: DisaggMilestone)
         -> Option<memserve::server::ClientHandle> {
    let rt = RT.as_ref()?.clone();
    Some(
        ServeCluster::start(
            ServeOptions {
                config: cfg,
                milestone,
                real_sleep: false,
            },
            rt,
        )
        .unwrap(),
    )
}

fn toks(n: usize, seed: u32) -> Vec<u32> {
    (0..n as u32)
        .map(|i| (i.wrapping_mul(2654435761).wrapping_add(seed)) % 2048)
        .collect()
}

fn sampling(max_new: usize) -> SamplingParams {
    SamplingParams {
        max_new_tokens: max_new,
        eos_token: u32::MAX,
        ..Default::default()
    }
}

const T: Duration = Duration::from_secs(120);

#[test]
fn colocated_caching_end_to_end() {
    let Some(c) = start(config(0, 0, 1, true), DisaggMilestone::PdCaching3)
    else {
        return;
    };
    let prompt = toks(60, 1);
    let r1 = c.submit(prompt.clone(), 1, sampling(8)).unwrap();
    let (g1, rec1) = c.collect(r1, T).unwrap();
    assert_eq!(g1.len(), 8);
    assert_eq!(rec1.cached_tokens, 0);
    // Same prompt again: cache hit, identical greedy output.
    let r2 = c.submit(prompt.clone(), 1, sampling(8)).unwrap();
    let (g2, rec2) = c.collect(r2, T).unwrap();
    assert!(rec2.cached_tokens >= 48, "cached={}", rec2.cached_tokens);
    assert_eq!(g1, g2, "caching changed generation");
    c.shutdown();
}

#[test]
fn disaggregated_matches_colocated_output() {
    // Greedy decode must be bit-identical whether the request runs on a
    // colocated instance or splits across 1P1D — the strongest
    // composition check we have.
    let Some(colo) = start(config(0, 0, 1, true), DisaggMilestone::PdCaching3)
    else {
        return;
    };
    let prompt = toks(50, 2);
    let r = colo.submit(prompt.clone(), 1, sampling(10)).unwrap();
    let (g_colo, _) = colo.collect(r, T).unwrap();
    colo.shutdown();

    let disagg = start(config(1, 1, 0, true), DisaggMilestone::PdCaching3)
        .unwrap();
    let r = disagg.submit(prompt.clone(), 1, sampling(10)).unwrap();
    let (g_dis, rec) = disagg.collect(r, T).unwrap();
    assert_eq!(g_colo, g_dis, "disaggregation changed generation");
    // Prefill and decode ran on different instances.
    assert_ne!(rec.prefill_instance, rec.decode_instance);
    disagg.shutdown();
}

#[test]
fn disaggregated_multi_turn_caching_grows() {
    let Some(c) = start(config(1, 1, 0, true), DisaggMilestone::PdCaching3)
    else {
        return;
    };
    let mut ctx = toks(40, 3);
    let mut cached_history = vec![];
    for turn in 0..3 {
        let rid = c.submit(ctx.clone(), 7, sampling(6)).unwrap();
        let (generated, rec) = c.collect(rid, T).unwrap();
        cached_history.push(rec.cached_tokens);
        ctx.extend(generated);
        ctx.extend(toks(6, 100 + turn));
    }
    assert_eq!(cached_history[0], 0);
    assert!(cached_history[1] >= 32, "{cached_history:?}");
    // Milestone 3: decode KV flowed back, so turn-2 cache covers turn-1's
    // *generated* tokens too (strictly more than the prompt-only case).
    assert!(
        cached_history[2] > cached_history[1],
        "{cached_history:?}"
    );
    // Wire carried real KV payloads.
    assert!(c.net_stats().payload_bytes > 0);
    c.shutdown();
}

#[test]
fn milestone_basic_does_not_cache() {
    let Some(c) = start(config(1, 1, 0, false), DisaggMilestone::PdBasic)
    else {
        return;
    };
    let prompt = toks(48, 4);
    for _ in 0..2 {
        let rid = c.submit(prompt.clone(), 1, sampling(4)).unwrap();
        let (_, rec) = c.collect(rid, T).unwrap();
        assert_eq!(rec.cached_tokens, 0);
    }
    c.shutdown();
}

#[test]
fn parallel_sessions_interleave() {
    let Some(c) = start(config(0, 0, 2, true), DisaggMilestone::PdCaching3)
    else {
        return;
    };
    // Submit 6 requests at once across 3 sessions; all must finish with
    // deterministic outputs per prompt.
    let prompts: Vec<Vec<u32>> =
        (0..6).map(|i| toks(30 + i * 7, 50 + i as u32)).collect();
    let rids: Vec<u64> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| c.submit(p.clone(), i as u64 % 3, sampling(5)).unwrap())
        .collect();
    let mut outs = vec![];
    for rid in rids {
        let (g, rec) = c.collect(rid, T).unwrap();
        assert_eq!(g.len(), 5);
        assert!(rec.completion >= rec.first_token);
        outs.push(g);
    }
    // Re-run one of them; result identical.
    let rid = c.submit(prompts[2].clone(), 9, sampling(5)).unwrap();
    let (g, _) = c.collect(rid, T).unwrap();
    assert_eq!(g, outs[2]);
    c.shutdown();
}

#[test]
fn drain_migrates_cache_and_join_scales_up() {
    let Some(c) = start(config(2, 1, 0, true), DisaggMilestone::PdCaching3)
    else {
        return;
    };
    // Warm one prefill instance's cache and learn which one served it.
    let prompt = toks(64, 7);
    let r1 = c.submit(prompt.clone(), 1, sampling(4)).unwrap();
    let (g1, rec1) = c.collect(r1, T).unwrap();
    let holder = InstanceId(rec1.prefill_instance);
    assert_eq!(c.lifecycle_state(holder), Some(InstanceState::Active));
    // Scale up, then drain the cache holder: its hot prefix must be
    // migrated (really shipped over the fabric + re-indexed), not lost.
    let newbie = c.join(InstanceKind::PrefillOnly).unwrap();
    assert_eq!(c.lifecycle_state(newbie), Some(InstanceState::Active));
    let report = c.drain(holder, T).unwrap();
    assert!(report.migrated_prefixes >= 1, "nothing migrated: {report:?}");
    assert!(report.migrated_blocks >= 4, "{report:?}");
    assert_eq!(
        c.lifecycle_state(holder),
        Some(InstanceState::Decommissioned)
    );
    assert!(c.instances().iter().all(|(i, _)| *i != holder));
    // The same prompt is still a fleet-wide cache hit, served by a
    // survivor, with bit-identical greedy output (migrated KV intact).
    let r2 = c.submit(prompt.clone(), 1, sampling(4)).unwrap();
    let (g2, rec2) = c.collect(r2, T).unwrap();
    assert_ne!(InstanceId(rec2.prefill_instance), holder);
    assert!(
        rec2.cached_tokens >= 48,
        "cache lost across drain: {}",
        rec2.cached_tokens
    );
    assert_eq!(g1, g2, "migrated KV changed generation");
    c.shutdown();
}

#[test]
fn drain_waits_for_inflight_requests() {
    let Some(c) = start(config(2, 1, 0, true), DisaggMilestone::PdCaching3)
    else {
        return;
    };
    // Fire a batch, then immediately drain whichever instance serves
    // session 0's request: zero request loss required.
    let rids: Vec<u64> = (0..4)
        .map(|i| c.submit(toks(48, 300 + i), i as u64, sampling(4)).unwrap())
        .collect();
    let victim = c.instances()[0].0;
    c.drain(victim, T).unwrap();
    for rid in rids {
        let (g, _) = c.collect(rid, T).unwrap();
        assert_eq!(g.len(), 4, "request lost across drain");
    }
    // New work keeps flowing on the shrunken fleet.
    let r = c.submit(toks(32, 999), 9, sampling(3)).unwrap();
    let (g, rec) = c.collect(r, T).unwrap();
    assert_eq!(g.len(), 3);
    assert_ne!(InstanceId(rec.prefill_instance), victim);
    c.shutdown();
}

#[test]
fn gs_failover_restores_routing_state_mid_run() {
    // Replicated global scheduler (ISSUE 4, resharded by ISSUE 5):
    // with 2 follower replicas over 2 prefix-range shards, crashing
    // the GS primary mid-run must lose zero requests AND zero locality
    // state — each shard's promoted follower replica (plus that
    // shard's retained delta-log suffix) restores the full prompt
    // tree, so the warm prompt still routes to its cache holder
    // afterwards.
    let mut cfg = config(2, 1, 0, true);
    cfg.scheduler.gs_replicas = 2;
    cfg.scheduler.gs_shards = 2;
    let Some(c) = start(cfg, DisaggMilestone::PdCaching3) else {
        return;
    };
    // Warm one prefill instance and learn which one holds the cache.
    let prompt = toks(64, 11);
    let r1 = c.submit(prompt.clone(), 1, sampling(4)).unwrap();
    let (g1, rec1) = c.collect(r1, T).unwrap();
    let holder = rec1.prefill_instance;
    // In-flight work across the crash: fire a batch, then kill the
    // primary GS before collecting.
    let rids: Vec<u64> = (0..3)
        .map(|i| c.submit(toks(40, 400 + i), 2 + i as u64, sampling(3)).unwrap())
        .collect();
    let promoted = c.fail_gs_primary(T).unwrap();
    assert_eq!(promoted.len(), 2, "one promotion per shard");
    let (head, acks) = c.gs_replication_status();
    for &(shard, target) in &promoted {
        assert!(
            acks.iter().any(|(f, _)| *f == target),
            "shard {shard}'s promoted follower {target} left the \
             replica set; head={head}"
        );
    }
    for rid in rids {
        let (g, _) = c.collect(rid, T).unwrap();
        assert_eq!(g.len(), 3, "request lost across GS failover");
    }
    // The warm prompt must still be a cache hit on the SAME holder:
    // the crash lost the primary's tree, so a hit here proves the
    // promoted replica carried the ownership state over.
    let r2 = c.submit(prompt.clone(), 1, sampling(4)).unwrap();
    let (g2, rec2) = c.collect(r2, T).unwrap();
    assert_eq!(
        rec2.prefill_instance, holder,
        "locality lost across GS failover"
    );
    assert!(
        rec2.cached_tokens >= 48,
        "cache state lost across GS failover: {}",
        rec2.cached_tokens
    );
    assert_eq!(g1, g2, "failover changed generation");
    c.shutdown();
}

#[test]
fn failover_reroutes_requests() {
    let Some(c) = start(config(0, 0, 2, true), DisaggMilestone::PdCaching3)
    else {
        return;
    };
    // Kill instance 0; heartbeats stop; after the sweep the survivor
    // serves everything.
    let victim = c.instances()[0].0;
    c.kill(victim);
    std::thread::sleep(Duration::from_millis(1500)); // > 5 * 200ms + margin
    assert!(!c.is_alive(victim), "victim still considered alive");
    for i in 0..4 {
        let rid = c.submit(toks(24, 200 + i), i as u64, sampling(3)).unwrap();
        let (g, rec) = c.collect(rid, T).unwrap();
        assert_eq!(g.len(), 3);
        assert_ne!(rec.decode_instance, victim.0);
    }
    c.shutdown();
}
