//! Fig 20 (repo-original): timeline, attribution, watchdog (ISSUE 9).
//!
//! Part 1 (`fig20_overhead`): the fig19 hot route path with the FULL
//! ISSUE 9 analysis layer riding along — registry + retire-side
//! attribution digests per route, plus a timeline frame + watchdog
//! pass every 1024 routes (the collector-cadence work, folded into the
//! measured loop so the number is an upper bound on the real tax).
//! `MEMSERVE_FIG20_GATE=1` asserts instrumented ≥ 0.95× bare median
//! throughput (`MEMSERVE_GATE_ATTEMPTS` re-measures, default 3).
//!
//! Part 2 (`fig20_attrib`): attribution-sums-to-wall, on both clocks.
//! Virtual: a real disaggregated sim with `observe: true` — for every
//! completed request, [`breakdown`]'s phase sum must reconstruct the
//! span's wall time within 1% (the sim closes phases edge-to-edge, so
//! the error is float noise). Live: the same span protocol driven by
//! `Instant` with real sleeps through a real `TraceSink` — same 1%
//! bound on wall-clock floats.
//!
//! Part 3 (`fig20_watchdog`): a seeded replication stall
//! (`replication_drop: 1.0`, no failover — followers never catch up,
//! so per-shard ack lag grows every window) must fire a
//! `repl_lag_growing` alert within a few windows of onset; the same
//! trace with lossless replication must fire ZERO alerts. The timeline
//! JSON lands in the bench sink for CI upload.
//!
//! Env knobs (used by the CI smoke job):
//! * `MEMSERVE_FIG20_MODE` — `overhead`, `attrib`, `watchdog`,
//!   anything else/unset runs all three;
//! * `MEMSERVE_FIG20_GATE` — `1` asserts the overhead floor.

use memserve::engine::DisaggMilestone;
use memserve::mempool::InstanceId;
use memserve::obs::trace::phase;
use memserve::obs::watchdog::rule;
use memserve::obs::{
    breakdown, trace, AttribBook, Registry, RetireSample, Timeline,
    TraceSink, Watchdog,
};
use memserve::scheduler::cost_model::OperatorCostModel;
use memserve::scheduler::prompt_tree::InstanceKind;
use memserve::scheduler::router::GlobalScheduler;
use memserve::scheduler::PolicyKind;
use memserve::sim::{SimConfig, Simulation};
use memserve::util::bench::{
    bench_json_dir, black_box, gate_attempts, time_adaptive, Table,
};
use memserve::workload::{ArrivalPlan, WorkloadKind, WorkloadSpec};

fn prompt(n: usize, seed: u32) -> Vec<u32> {
    (0..n as u32)
        .map(|i| (i.wrapping_mul(2654435761).wrapping_add(seed)) % 50_000)
        .collect()
}

// ---------------------------------------------------------------------
// Part 1: route path + the full analysis layer vs bare.
// ---------------------------------------------------------------------

/// The fig15/fig19 hot-fleet scheduler: N prefill instances, the 4K
/// prompt cached on every one, 4 unique prompts each for tree bulk.
fn hot_scheduler(n: usize, hot: &[u32]) -> GlobalScheduler {
    const BT: usize = 16;
    let mut gs = GlobalScheduler::new(
        PolicyKind::PromptTree,
        OperatorCostModel::paper_13b(),
        BT,
        0.0,
    );
    for i in 0..n {
        gs.add_instance(InstanceId(i as u32), InstanceKind::PrefillOnly);
    }
    for i in 0..n {
        let id = InstanceId(i as u32);
        gs.trees.record(id, hot, 1.0);
        for k in 0..4u32 {
            gs.trees.record(id, &prompt(4096, 1000 + (i as u32) * 4 + k),
                            1.0);
        }
    }
    gs
}

/// One measurement of both variants; returns (bare, instrumented)
/// median routes/sec.
fn overhead_run(n: usize) -> (f64, f64) {
    let hot = prompt(4096, 1);

    // min_iters 2500 (not fig19's 200): the instrumented loop's
    // collector-cadence burst fires every 1024 routes and the second
    // burst closes the first timeline frame, so both variants must run
    // well past 2048 iterations even on a slow box.
    let mut bare = hot_scheduler(n, &hot);
    let mut bare_t = time_adaptive(150.0, 2500, || {
        black_box(bare.route(&hot, 7, 2.0).unwrap());
    });

    let mut inst = hot_scheduler(n, &hot);
    let reg = Registry::new(true);
    inst.attach_obs(&reg, None);
    let attrib = AttribBook::new(&reg);
    // 0.25 virtual seconds per frame at 1024 routes/frame below, so
    // every collector-cadence burst closes a frame and pays the full
    // snapshot + diff + watchdog pass inside the timed loop.
    let timeline = Timeline::with_window(0.25);
    let mut watchdog = Watchdog::default();
    let mut i = 0u64;
    let mut inst_t = time_adaptive(150.0, 2500, || {
        let out = inst.route(&hot, 7, 2.0).unwrap();
        // Retire-side digests: queue/TTFT/TBT + cost-error histograms,
        // per route — the steady-state ISSUE 9 hot-path cost.
        attrib.observe_retire(0, &RetireSample {
            arrival: 0.0,
            scheduled: 0.001,
            first_token: 0.010,
            completion: 0.020,
            output_tokens: 8,
            predicted_prefill_s: out.expected_prefill_s.max(1e-6),
        });
        i += 1;
        // Collector-cadence work (in production this runs ~2×/sec on
        // the collector thread, not on the route path — folding it in
        // here makes the measured tax an upper bound).
        if i % 1024 == 0 && timeline.observe(reg.snapshot(i as f64 * 2.5e-4))
        {
            black_box(watchdog.check(&timeline.frames()).len());
        }
        black_box(out);
    });
    // Sanity: the analysis layer actually ran inside the timed loop.
    assert!(!timeline.is_empty(), "timeline never closed a frame");
    assert!(
        reg.snapshot(0.0).counter_sum("sched.routes") >= inst_t.len() as u64,
        "sched.routes did not count the instrumented loop"
    );
    (1e6 / bare_t.p50().max(1e-9), 1e6 / inst_t.p50().max(1e-9))
}

fn overhead(n: usize, gate: bool) {
    let mut table = Table::new("fig20_overhead", &[
        "instances", "variant", "routes_per_sec", "vs_bare",
    ]);
    println!(
        "\n-- route path + timeline/attribution/watchdog vs bare, hot \
         fleet N={n} --"
    );
    let (mut bare, mut inst) = overhead_run(n);
    let mut ratio = inst / bare.max(1e-9);
    if gate {
        for attempt in 0..gate_attempts() {
            if ratio >= 0.95 {
                break;
            }
            println!(
                "  gate attempt {}: {ratio:.3}x — re-measuring",
                attempt + 1
            );
            let (b, i) = overhead_run(n);
            bare = b;
            inst = i;
            ratio = inst / bare.max(1e-9);
        }
    }
    table.row(vec![
        n.to_string(),
        "bare".into(),
        format!("{bare:.0}"),
        "1.00x".into(),
    ]);
    table.row(vec![
        n.to_string(),
        "instrumented".into(),
        format!("{inst:.0}"),
        format!("{ratio:.3}x"),
    ]);
    println!(
        "  bare {bare:9.0} routes/sec   instrumented {inst:9.0} \
         routes/sec   ({ratio:.3}x)"
    );
    table.finish();
    if gate {
        assert!(
            ratio >= 0.95,
            "MEMSERVE_FIG20_GATE: analysis-layer route path is \
             {ratio:.3}x bare median throughput ({inst:.0} vs {bare:.0} \
             routes/sec), below the 0.95 floor"
        );
        println!("  gate: {ratio:.3}x >= 0.95x -- pass");
    }
}

// ---------------------------------------------------------------------
// Part 2: attribution sums to wall time on both clocks.
// ---------------------------------------------------------------------

fn check_sums(
    name: &str,
    events: &[memserve::obs::TraceEvent],
    expect_spans: usize,
) -> (usize, f64) {
    let map = breakdown(events);
    let mut checked = 0usize;
    let mut worst = 0.0f64;
    for (span, b) in &map {
        let wall = b.wall();
        assert!(wall > 0.0, "{name}: span {span} has zero wall time");
        let err = (b.total() - wall).abs() / wall;
        assert!(
            err <= 0.01,
            "{name}: span {span} phase sum {:.6}s vs wall {:.6}s \
             ({:.3}% off, > 1%)",
            b.total(),
            wall,
            err * 100.0
        );
        worst = worst.max(err);
        checked += 1;
    }
    assert!(
        checked >= expect_spans,
        "{name}: decomposed {checked} spans, expected >= {expect_spans}"
    );
    (checked, worst)
}

fn attribution() {
    let mut table = Table::new("fig20_attrib", &[
        "clock", "spans", "worst_sum_vs_wall_err",
    ]);
    println!(
        "\n-- attribution: phase sums must reconstruct span wall time \
         within 1%, virtual and live clocks --"
    );

    // Virtual clock: a real disaggregated sim with observation on.
    let spec =
        WorkloadSpec::generate(WorkloadKind::Loogle, 30, 35, 2048, 4096);
    let plan = ArrivalPlan::poisson(&spec, 4.0, 35);
    let total = spec.total_requests();
    let cfg = SimConfig {
        prefill_instances: 2,
        decode_instances: 2,
        colocated_instances: 0,
        caching: true,
        milestone: DisaggMilestone::PdCaching3,
        observe: true,
        ..Default::default()
    };
    let rep = Simulation::new(cfg, spec, &plan).run();
    assert_eq!(rep.metrics.records.len(), total);
    let obs = rep.obs.as_ref().expect("observe: true fills obs");
    let (v_spans, v_err) = check_sums("virtual", &obs.trace.events(), total);
    // The retire-side digests saw every request too.
    let ttft: u64 = (0..4)
        .map(|i| {
            obs.view
                .snapshot
                .histo(&format!("lat.ttft_us{{instance={i}}}"))
                .map(|h| h.count)
                .unwrap_or(0)
        })
        .sum();
    assert_eq!(
        ttft as usize, total,
        "lat.ttft_us digests missed requests"
    );
    table.row(vec![
        "virtual".into(),
        v_spans.to_string(),
        format!("{:.2e}", v_err),
    ]);

    // Live clock: the same span protocol on Instant time with real
    // sleeps, one clock read per phase boundary (the leader/instance
    // discipline: each phase begins where the last ended).
    let sink = TraceSink::new(true);
    let t0 = std::time::Instant::now();
    let now = || t0.elapsed().as_secs_f64();
    let live_spans = 8u64;
    for rid in 0..live_spans {
        let span = trace::request_span(rid);
        let a = now();
        sink.complete(span, phase::ROUTE, u32::MAX, a, a);
        sink.begin(span, phase::QUEUE, u32::MAX, a);
        std::thread::sleep(std::time::Duration::from_millis(2));
        let b = now();
        sink.end(span, phase::QUEUE, b);
        sink.begin(span, phase::PREFILL, 0, b);
        std::thread::sleep(std::time::Duration::from_millis(3));
        let c = now();
        sink.end(span, phase::PREFILL, c);
        sink.begin(span, phase::KV_TRANSFER, 0, c);
        std::thread::sleep(std::time::Duration::from_millis(1));
        let d = now();
        sink.end(span, phase::KV_TRANSFER, d);
        sink.begin(span, phase::DECODE, 1, d);
        std::thread::sleep(std::time::Duration::from_millis(4));
        let e = now();
        sink.end(span, phase::DECODE, e);
        sink.complete(span, phase::RETIRE, 1, e, e);
    }
    let (l_spans, l_err) =
        check_sums("live", &sink.events(), live_spans as usize);
    table.row(vec![
        "live".into(),
        l_spans.to_string(),
        format!("{:.2e}", l_err),
    ]);
    println!(
        "  virtual: {v_spans} spans, worst err {v_err:.2e}   live: \
         {l_spans} spans, worst err {l_err:.2e}"
    );
    table.finish();
}

// ---------------------------------------------------------------------
// Part 3: watchdog — seeded stall fires, clean trace is silent.
// ---------------------------------------------------------------------

fn stall_cfg(drop: f64) -> SimConfig {
    SimConfig {
        prefill_instances: 2,
        decode_instances: 2,
        colocated_instances: 0,
        caching: true,
        milestone: DisaggMilestone::PdCaching3,
        gs_shards: 1,
        gs_replicas: 1,
        replication_drop: drop,
        observe: true,
        ..Default::default()
    }
}

fn stall_workload() -> (WorkloadSpec, ArrivalPlan, usize) {
    let spec =
        WorkloadSpec::generate(WorkloadKind::Loogle, 30, 35, 2048, 4096);
    let plan = ArrivalPlan::poisson(&spec, 4.0, 35);
    let total = spec.total_requests();
    (spec, plan, total)
}

fn watchdog_part() {
    let mut table = Table::new("fig20_watchdog", &[
        "variant", "requests", "frames", "alerts", "first_alert_s",
    ]);
    println!(
        "\n-- watchdog: total replication loss (no failover) must fire \
         repl_lag_growing within a few windows; lossless must be silent --"
    );

    // Seeded stall: every replication delivery drops, gap repair never
    // wins, so the follower's ack lag grows every window that carries
    // new deltas. The request path is untouched (zero request loss).
    let (spec, plan, total) = stall_workload();
    let rep = Simulation::new(stall_cfg(1.0), spec, &plan).run();
    assert_eq!(
        rep.metrics.records.len(),
        total,
        "stalled replication must not lose requests"
    );
    let obs = rep.obs.as_ref().expect("observe: true fills obs");
    assert!(
        !obs.alerts.is_empty(),
        "seeded replication stall fired no watchdog alert"
    );
    let lag = obs
        .alerts
        .iter()
        .find(|a| a.rule == rule::REPL_LAG_GROWING)
        .expect("stall must fire repl_lag_growing specifically");
    // Detection latency: the rule needs k_windows+1 strictly-growing
    // frames (default k=3, 1s windows), so the alert must land within
    // the first handful of windows — not at trace end.
    let k = memserve::obs::WatchdogConfig::default().k_windows as f64;
    assert!(
        lag.at <= (k + 4.0) * 1.0,
        "repl_lag_growing fired at {:.1}s — later than K+4 windows",
        lag.at
    );
    // The alert is also in the flight ring, structured.
    let flight_alerts =
        obs.flight.of_kind(memserve::obs::flight::kind::ALERT).len();
    assert!(
        flight_alerts >= obs.alerts.len(),
        "flight ring missed watchdog alerts"
    );
    assert!(!obs.timeline.is_empty(), "timeline closed no frames");
    table.row(vec![
        "stalled".into(),
        total.to_string(),
        obs.timeline.len().to_string(),
        obs.alerts.len().to_string(),
        format!("{:.1}", lag.at),
    ]);
    println!(
        "  stalled: {} alerts over {} frames, repl_lag_growing at \
         {:.1}s",
        obs.alerts.len(),
        obs.timeline.len(),
        lag.at
    );
    // Timeline JSON artifact for CI upload.
    if let Some(dir) = bench_json_dir() {
        if std::fs::create_dir_all(&dir).is_ok() {
            let tp = format!("{dir}/fig20_timeline.json");
            match std::fs::write(&tp, obs.timeline.to_json().to_string()) {
                Ok(()) => println!("[saved {tp}]"),
                Err(e) => eprintln!("[warn] could not save timeline: {e}"),
            }
        }
        if let Some(p) = obs.flight.dump_to(&dir, "fig20_flight") {
            println!("[saved {p}]");
        }
    }

    // Clean run: same trace, lossless replication — zero alerts.
    let (spec, plan, total) = stall_workload();
    let rep = Simulation::new(stall_cfg(0.0), spec, &plan).run();
    assert_eq!(rep.metrics.records.len(), total);
    let obs = rep.obs.as_ref().expect("observe: true fills obs");
    assert!(
        obs.alerts.is_empty(),
        "healthy trace fired spurious alerts: {:?}",
        obs.alerts
    );
    table.row(vec![
        "clean".into(),
        total.to_string(),
        obs.timeline.len().to_string(),
        "0".into(),
        "-".into(),
    ]);
    println!(
        "  clean: 0 alerts over {} frames",
        obs.timeline.len()
    );
    table.finish();
    println!(
        "\nExpected shape: the stalled run's ack-lag ramp trips \
         repl_lag_growing once (re-armed only if the lag ever stops \
         growing), the clean run is silent end to end."
    );
}

fn main() {
    let mode = std::env::var("MEMSERVE_FIG20_MODE").unwrap_or_default();
    let n: usize = std::env::var("MEMSERVE_FIG20_N")
        .ok()
        .and_then(|s| s.trim().parse().ok())
        .unwrap_or(16)
        .max(1);
    let gate = std::env::var("MEMSERVE_FIG20_GATE").as_deref() == Ok("1");
    let all = !matches!(mode.as_str(), "overhead" | "attrib" | "watchdog");
    if all || mode == "overhead" {
        overhead(n, gate);
    }
    if all || mode == "attrib" {
        attribution();
    }
    if all || mode == "watchdog" {
        watchdog_part();
    }
}
