//! Fig 15 reproduction: global scheduling policies vs share ratio.
//! 80 LooGLE sessions (~250 requests) on a 3P1D cluster; the share ratio
//! duplicates the session set so identical request streams arrive 1–4×
//! (the paper's "ratio of the number of identical requests").

use memserve::scheduler::PolicyKind;
use memserve::sim::{SimConfig, Simulation};
use memserve::util::bench::Table;
use memserve::workload::{ArrivalPlan, WorkloadKind, WorkloadSpec};

fn main() {
    let base = WorkloadSpec::generate(WorkloadKind::Loogle, 80, 15, 2048,
                                      4096);
    println!(
        "base workload: {} sessions, {} requests",
        base.sessions.len(),
        base.total_requests()
    );
    let mut table = Table::new("fig15_scheduler", &[
        "share_ratio", "policy", "n", "cached_ratio", "ttft_mean_s",
        "ttft_p99_s", "jct_p99_s",
    ]);
    for &share in &[1usize, 2, 3, 4] {
        let mut spec = base.clone();
        for r in 1..share {
            let mut dup = base.clone();
            for s in &mut dup.sessions {
                s.id += (r * 10_000) as u64;
            }
            spec.sessions.extend(dup.sessions);
        }
        let plan = ArrivalPlan::poisson(&spec, 10.0, 15);
        for policy in [
            PolicyKind::LeastLoad,
            PolicyKind::SessionId,
            PolicyKind::PromptTree,
        ] {
            let cfg = SimConfig {
                prefill_instances: 3,
                decode_instances: 1,
                policy,
                ..Default::default()
            };
            let rep = Simulation::new(cfg, spec.clone(), &plan).run();
            let m = &rep.metrics;
            table.row(vec![
                share.to_string(),
                policy.name().into(),
                m.records.len().to_string(),
                format!("{:.3}", m.mean_cached_ratio()),
                format!("{:.4}", m.ttft().mean),
                format!("{:.4}", m.ttft().p99),
                format!("{:.4}", m.jct().p99),
            ]);
        }
    }
    table.finish();
    println!(
        "\nExpected shape (paper Fig 15): prompt-tree >= session-id >= \
         least-load on P99 TTFT; the prompt-tree advantage grows with \
         share ratio (only it can see inter-session sharing) — the paper \
         reports 59% P99 TTFT improvement over intra-session scheduling."
    );
}
