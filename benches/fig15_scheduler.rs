//! Fig 15 reproduction + routing-path scaling.
//!
//! Part 1 (`fig15_route_sweep`): per-route cost of the **fused** global
//! prompt tree vs the seed's **per-instance** reference trees, swept
//! over instance counts with a 4K-token hot prompt cached fleet-wide
//! (the popular-system-prompt case where the per-instance walk is
//! O(instances × prompt_blocks)). The fused tree should stay near-flat
//! in instance count.
//!
//! Part 2 (`fig15_scheduler`): the paper's policy-vs-share-ratio sim —
//! 80 LooGLE sessions (~250 requests) on a 3P1D cluster; the share
//! ratio duplicates the session set so identical request streams arrive
//! 1–4×.
//!
//! Part 3 (`fig15_thread_sweep`): the multi-core data plane — T
//! submitter threads route a shared workload through S shard-pinned
//! worker threads ([`memserve::scheduler::data_plane::ShardWorkerPool`])
//! measuring routes/sec and per-delta apply cost. T=1 is asserted
//! decision-identical to the monolithic sequential scheduler (every T
//! is, in fact — the determinism argument in the data-plane module
//! docs), and `MEMSERVE_FIG15_GATE=1` turns the T=4-vs-T=1 comparison
//! into a hard assert for CI.
//!
//! Env knobs (used by the CI smoke job):
//! * `MEMSERVE_FIG15_MODE` — `sweep` (part 1 only), `sim` (part 2
//!   only), `threads` (part 3 only), anything else/unset runs parts
//!   1 + 2 (part 3 is opt-in so the default output stays byte-stable);
//! * `MEMSERVE_FIG15_N` — comma-separated instance counts for the
//!   sweep (default `4,16,64,256`);
//! * `MEMSERVE_FIG15_T` — comma-separated submitter thread counts for
//!   the thread sweep (default `1,2,4,8`);
//! * `MEMSERVE_FIG15_S` — shard/worker count for the thread sweep
//!   (default `2`);
//! * `MEMSERVE_FIG15_GATE` — `1` asserts routes/sec at T=4 beats the
//!   T=1 baseline (3 attempts before failing, contended CI runners
//!   being what they are).

use std::sync::Arc;
use std::time::Instant;

use memserve::elastic::delta::DeltaEvent;
use memserve::mempool::InstanceId;
use memserve::scheduler::cost_model::OperatorCostModel;
use memserve::scheduler::data_plane::{LoadVec, ShardWorkerPool};
use memserve::scheduler::policy::{decide, Candidate, Decision};
use memserve::scheduler::prompt_tree::InstanceKind;
use memserve::scheduler::prompt_tree_ref::RefGlobalPromptTrees;
use memserve::scheduler::router::{GlobalScheduler, InstanceLoad};
use memserve::scheduler::PolicyKind;
use memserve::sim::{SimConfig, Simulation};
use memserve::util::bench::{black_box, time_adaptive, Table};
use memserve::workload::{ArrivalPlan, WorkloadKind, WorkloadSpec};

fn prompt(n: usize, seed: u32) -> Vec<u32> {
    (0..n as u32)
        .map(|i| (i.wrapping_mul(2654435761).wrapping_add(seed)) % 50_000)
        .collect()
}

/// Instance-count sweep in two fleet regimes: **hot** — the 4K prompt is
/// cached on *every* instance (the popular-system-prompt case where the
/// per-instance reference pays a full O(prompt_blocks) walk per
/// instance), and **cold** — only instance 0 caches it, so the
/// reference's other walks miss at the root and the gap honestly
/// shrinks (each walk is one hash probe, not 256). Per-instance unique
/// prompts provide tree bulk in both regimes.
fn route_sweep(ns: &[usize]) {
    const BT: usize = 16;
    let mut table = Table::new("fig15_route_sweep", &[
        "instances", "prompt_tokens", "fleet", "variant", "route_us_mean",
        "route_us_p99",
    ]);
    println!(
        "\n-- routing cost, 4K-token prompt (hot fleet = cached \
         everywhere; cold fleet = cached on one instance) --\n\
         (fused = one walk with instance bitsets; per_instance_ref = the \
         seed's one-tree-per-instance walk)"
    );
    for &n in ns {
        for fleet in ["hot", "cold"] {
            let hot = prompt(4096, 1);
            let mut gs = GlobalScheduler::new(
                PolicyKind::PromptTree,
                OperatorCostModel::paper_13b(),
                BT,
                0.0,
            );
            let mut refr = RefGlobalPromptTrees::new(BT, 0.0);
            for i in 0..n {
                let id = InstanceId(i as u32);
                gs.add_instance(id, InstanceKind::PrefillOnly);
                refr.add_instance(id, InstanceKind::PrefillOnly);
            }
            for i in 0..n {
                let id = InstanceId(i as u32);
                if fleet == "hot" || i == 0 {
                    gs.trees.record(id, &hot, 1.0);
                    refr.record(id, &hot, 1.0);
                }
                for k in 0..4u32 {
                    let p = prompt(4096, 1000 + (i as u32) * 4 + k);
                    gs.trees.record(id, &p, 1.0);
                    refr.record(id, &p, 1.0);
                }
            }
            let cost = OperatorCostModel::paper_13b();
            // The seed routing path, end to end: per-instance tree walks
            // → candidate list → Eq. 1 decision. One definition serves
            // both the sanity assert and the timing loop.
            let ref_route = |refr: &RefGlobalPromptTrees| {
                let matches = refr.match_all(&hot);
                let candidates: Vec<Candidate> = matches
                    .iter()
                    .map(|&(id, matched)| Candidate {
                        instance: id,
                        queued_tokens: 0,
                        queued_cached_ratio: 0.0,
                        matched_tokens: matched,
                        pressure: 0.0,
                    })
                    .collect();
                decide(
                    PolicyKind::PromptTree,
                    &candidates,
                    hot.len(),
                    7,
                    |x, y| cost.exec(x, y),
                )
            };
            // Sanity: both paths must route identically before timing.
            let fused_out = gs.route(&hot, 7, 2.0).unwrap();
            assert_eq!(
                fused_out.decision,
                ref_route(&refr),
                "fused and reference routing diverged at N={n} ({fleet})"
            );

            let mut fused_t = time_adaptive(80.0, 100, || {
                black_box(gs.route(&hot, 7, 2.0).unwrap());
            });
            let mut ref_t = time_adaptive(80.0, 100, || {
                black_box(ref_route(&refr));
            });
            let (fm, rm) = (fused_t.mean(), ref_t.mean());
            table.row(vec![
                n.to_string(),
                "4096".into(),
                fleet.into(),
                "fused".into(),
                format!("{fm:.2}"),
                format!("{:.2}", fused_t.p99()),
            ]);
            table.row(vec![
                n.to_string(),
                "4096".into(),
                fleet.into(),
                "per_instance_ref".into(),
                format!("{rm:.2}"),
                format!("{:.2}", ref_t.p99()),
            ]);
            println!(
                "  N={n:4} {fleet:4}: fused {fm:8.2}us  ref {rm:8.2}us  \
                 ({:.1}x)",
                rm / fm.max(1e-9)
            );
        }
    }
    table.finish();
    println!(
        "\nExpected shape: fused per-route cost near-flat in N (the walk \
         is O(prompt_blocks) + word ops); the hot-fleet reference grows \
         ~linearly — ≥5x at N=64 — while the cold-fleet gap is smaller \
         (the reference's misses are cheap): honest bounds."
    );
}

/// The paper's Fig 15 policy sweep on the discrete-event simulator.
fn policy_sim() {
    let base = WorkloadSpec::generate(WorkloadKind::Loogle, 80, 15, 2048,
                                      4096);
    println!(
        "base workload: {} sessions, {} requests",
        base.sessions.len(),
        base.total_requests()
    );
    let mut table = Table::new("fig15_scheduler", &[
        "share_ratio", "policy", "n", "cached_ratio", "ttft_mean_s",
        "ttft_p99_s", "jct_p99_s",
    ]);
    for &share in &[1usize, 2, 3, 4] {
        let mut spec = base.clone();
        for r in 1..share {
            let mut dup = base.clone();
            for s in &mut dup.sessions {
                s.id += (r * 10_000) as u64;
            }
            spec.sessions.extend(dup.sessions);
        }
        let plan = ArrivalPlan::poisson(&spec, 10.0, 15);
        for policy in [
            PolicyKind::LeastLoad,
            PolicyKind::SessionId,
            PolicyKind::PromptTree,
        ] {
            let cfg = SimConfig {
                prefill_instances: 3,
                decode_instances: 1,
                policy,
                ..Default::default()
            };
            let rep = Simulation::new(cfg, spec.clone(), &plan).run();
            let m = &rep.metrics;
            table.row(vec![
                share.to_string(),
                policy.name().into(),
                m.records.len().to_string(),
                format!("{:.3}", m.mean_cached_ratio()),
                format!("{:.4}", m.ttft().mean),
                format!("{:.4}", m.ttft().p99),
                format!("{:.4}", m.jct().p99),
            ]);
        }
    }
    table.finish();
    println!(
        "\nExpected shape (paper Fig 15): prompt-tree >= session-id >= \
         least-load on P99 TTFT; the prompt-tree advantage grows with \
         share ratio (only it can see inter-session sharing) — the paper \
         reports 59% P99 TTFT improvement over intra-session scheduling."
    );
}

/// The thread-sweep workload: a fixed fleet, a seeded record set (so
/// routes hit real prefix matches), and a request stream reusing the
/// recorded seeds. Everything is deterministic in the seed so every T
/// routes the identical stream.
struct ThreadWorkload {
    n_inst: u32,
    records: Vec<(InstanceId, Vec<u32>)>,
    requests: Vec<(u64, Vec<u32>, u64)>,
    loads: LoadVec,
}

const TW_BT: usize = 16;

fn thread_workload(requests: usize) -> ThreadWorkload {
    let n_inst = 8u32;
    let records: Vec<(InstanceId, Vec<u32>)> = (0..n_inst * 8)
        .map(|r| (InstanceId(r % n_inst), prompt(512, 100 + r)))
        .collect();
    let requests: Vec<(u64, Vec<u32>, u64)> = (0..requests as u64)
        .map(|j| {
            // Reuse recorded seeds so most routes walk a cached chain.
            (j, prompt(512, 100 + (j as u32 * 7) % 64), j % 24)
        })
        .collect();
    let loads: LoadVec = Arc::new(
        (0..n_inst)
            .map(|i| {
                (InstanceId(i), InstanceLoad {
                    queued_tokens: (i as usize * 97) % 1024,
                    ..Default::default()
                })
            })
            .collect(),
    );
    ThreadWorkload { n_inst, records, requests, loads }
}

/// The monolithic sequential reference: today's single-owner scheduler
/// routing the same stream, returning its decisions (the bit-identity
/// baseline) and its wall-clock routes/sec.
fn monolithic_run(w: &ThreadWorkload, shards: usize)
                  -> (Vec<(u64, Decision)>, f64) {
    let mut gs = GlobalScheduler::with_shards(
        PolicyKind::PromptTree,
        OperatorCostModel::paper_13b(),
        TW_BT,
        0.0,
        shards,
    );
    for i in 0..w.n_inst {
        gs.trees.apply_delta(&DeltaEvent::Join {
            instance: InstanceId(i),
            kind: InstanceKind::PrefillOnly,
        });
    }
    for (inst, t) in &w.records {
        gs.trees.apply_delta(&DeltaEvent::Record {
            instance: *inst,
            tokens: t.clone(),
            now: 1.0,
        });
    }
    let start = Instant::now();
    let decisions: Vec<(u64, Decision)> = w
        .requests
        .iter()
        .map(|(id, p, session)| {
            for &(inst, load) in w.loads.iter() {
                gs.set_load(inst, load);
            }
            (*id, gs.route(p, *session, 2.0).unwrap().decision)
        })
        .collect();
    let rps = w.requests.len() as f64 / start.elapsed().as_secs_f64();
    (decisions, rps)
}

/// One pool run at T submitter threads: returns routes/sec, the
/// per-delta apply cost (µs), and the sorted (request, decision)
/// stream for the differential assert.
fn pool_run(w: &ThreadWorkload, shards: usize, threads: usize)
            -> (f64, f64, Vec<(u64, Decision)>) {
    let mut pool = ShardWorkerPool::new(
        shards,
        TW_BT,
        0.0,
        PolicyKind::PromptTree,
        OperatorCostModel::paper_13b(),
    );
    for i in 0..w.n_inst {
        pool.apply(&DeltaEvent::Join {
            instance: InstanceId(i),
            kind: InstanceKind::PrefillOnly,
        });
    }
    for (inst, t) in &w.records {
        pool.apply(&DeltaEvent::Record {
            instance: *inst,
            tokens: t.clone(),
            now: 1.0,
        });
    }
    pool.fence();
    let start = Instant::now();
    let mut got: Vec<(u64, Decision)> = std::thread::scope(|sc| {
        let mut joins = vec![];
        for t in 0..threads {
            let sub = pool.submitter();
            let w = &*w;
            joins.push(sc.spawn(move || {
                let mut out = vec![];
                for (id, p, session) in
                    w.requests.iter().skip(t).step_by(threads)
                {
                    let o = sub
                        .route(*id, p, *session, 2.0, &w.loads)
                        .unwrap();
                    out.push((*id, o.decision));
                }
                out
            }));
        }
        joins.into_iter().flat_map(|j| j.join().unwrap()).collect()
    });
    let rps = w.requests.len() as f64 / start.elapsed().as_secs_f64();
    got.sort_by_key(|&(id, _)| id);

    // Per-delta apply cost on the live pool: K prefix-keyed records
    // (the lock-free path — one channel send each), bounded by a fence
    // so every apply has landed before the clock stops.
    const K: usize = 4096;
    let dstart = Instant::now();
    for k in 0..K as u32 {
        pool.apply(&DeltaEvent::Record {
            instance: InstanceId(k % w.n_inst),
            tokens: prompt(64, 100 + (k % 64)),
            now: 3.0,
        });
    }
    pool.fence();
    let delta_us = dstart.elapsed().as_secs_f64() * 1e6 / K as f64;
    pool.shutdown();
    (rps, delta_us, got)
}

/// Part 3: routes/sec by submitter-thread count over S shard workers.
fn thread_sweep(ts: &[usize], shards: usize, gate: bool) {
    let w = thread_workload(1200);
    let mut table = Table::new("fig15_thread_sweep", &[
        "threads", "shards", "routes_per_sec", "delta_apply_us",
        "vs_monolithic",
    ]);
    let (expect, mono_rps) = monolithic_run(&w, shards);
    println!(
        "\n-- multi-core data plane: T submitters x {shards} shard \
         workers, {} requests --\n\
         monolithic sequential baseline: {mono_rps:.0} routes/sec",
        w.requests.len()
    );
    let mut measured: Vec<(usize, f64)> = vec![];
    for &t in ts {
        let (rps, delta_us, got) = pool_run(&w, shards, t);
        assert_eq!(
            got, expect,
            "T={t} S={shards}: decision stream diverged from the \
             monolithic reference"
        );
        measured.push((t, rps));
        table.row(vec![
            t.to_string(),
            shards.to_string(),
            format!("{rps:.0}"),
            format!("{delta_us:.3}"),
            format!("{:.2}x", rps / mono_rps.max(1e-9)),
        ]);
        println!(
            "  T={t}: {rps:9.0} routes/sec  ({:.2}x monolithic)  \
             delta apply {delta_us:.3}us",
            rps / mono_rps.max(1e-9)
        );
    }
    table.finish();
    println!(
        "\nExpected shape: routes/sec grows with T until the S workers \
         saturate (decisions are bit-identical at every T — the speedup \
         is free of semantic drift)."
    );
    if gate {
        // Contended-runner tolerance: re-measure up to 3 times before
        // declaring the scaling claim dead.
        let rate = |t: usize| {
            measured
                .iter()
                .find(|&&(mt, _)| mt == t)
                .map(|&(_, r)| r)
        };
        let (mut r1, mut r4) = (rate(1), rate(4));
        let mut ok = matches!((r1, r4), (Some(a), Some(b)) if b >= a);
        for attempt in 0..3 {
            if ok {
                break;
            }
            println!("  gate attempt {}: re-measuring T=1 vs T=4", attempt + 1);
            r1 = Some(pool_run(&w, shards, 1).0);
            r4 = Some(pool_run(&w, shards, 4).0);
            ok = r4.unwrap() >= r1.unwrap();
        }
        assert!(
            ok,
            "MEMSERVE_FIG15_GATE: T=4 ({:?} routes/sec) failed to beat \
             the T=1 baseline ({:?} routes/sec) on S={shards}",
            r4, r1
        );
        println!(
            "  gate: T=4 ({:.0}/s) >= T=1 ({:.0}/s) -- pass",
            r4.unwrap(),
            r1.unwrap()
        );
    }
}

fn main() {
    let mode = std::env::var("MEMSERVE_FIG15_MODE").unwrap_or_default();
    let ns: Vec<usize> = std::env::var("MEMSERVE_FIG15_N")
        .ok()
        .map(|s| {
            s.split(',')
                .filter_map(|x| x.trim().parse().ok())
                .collect::<Vec<usize>>()
        })
        .filter(|v| !v.is_empty())
        .unwrap_or_else(|| vec![4, 16, 64, 256]);
    if mode == "threads" {
        let ts: Vec<usize> = std::env::var("MEMSERVE_FIG15_T")
            .ok()
            .map(|s| {
                s.split(',')
                    .filter_map(|x| x.trim().parse().ok())
                    .collect::<Vec<usize>>()
            })
            .filter(|v| !v.is_empty())
            .unwrap_or_else(|| vec![1, 2, 4, 8]);
        let shards: usize = std::env::var("MEMSERVE_FIG15_S")
            .ok()
            .and_then(|s| s.trim().parse().ok())
            .unwrap_or(2)
            .max(1);
        let gate = std::env::var("MEMSERVE_FIG15_GATE").as_deref()
            == Ok("1");
        thread_sweep(&ts, shards, gate);
        return;
    }
    if mode != "sim" {
        route_sweep(&ns);
    }
    if mode != "sweep" {
        policy_sim();
    }
}
