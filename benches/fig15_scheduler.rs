//! Fig 15 reproduction + routing-path scaling.
//!
//! Part 1 (`fig15_route_sweep`): per-route cost of the **fused** global
//! prompt tree vs the seed's **per-instance** reference trees, swept
//! over instance counts with a 4K-token hot prompt cached fleet-wide
//! (the popular-system-prompt case where the per-instance walk is
//! O(instances × prompt_blocks)). The fused tree should stay near-flat
//! in instance count.
//!
//! Part 2 (`fig15_scheduler`): the paper's policy-vs-share-ratio sim —
//! 80 LooGLE sessions (~250 requests) on a 3P1D cluster; the share
//! ratio duplicates the session set so identical request streams arrive
//! 1–4×.
//!
//! Env knobs (used by the CI smoke job):
//! * `MEMSERVE_FIG15_MODE` — `sweep` (part 1 only), `sim` (part 2
//!   only), anything else/unset runs both;
//! * `MEMSERVE_FIG15_N` — comma-separated instance counts for the
//!   sweep (default `4,16,64,256`).

use memserve::mempool::InstanceId;
use memserve::scheduler::cost_model::OperatorCostModel;
use memserve::scheduler::policy::{decide, Candidate};
use memserve::scheduler::prompt_tree::InstanceKind;
use memserve::scheduler::prompt_tree_ref::RefGlobalPromptTrees;
use memserve::scheduler::router::GlobalScheduler;
use memserve::scheduler::PolicyKind;
use memserve::sim::{SimConfig, Simulation};
use memserve::util::bench::{black_box, time_adaptive, Table};
use memserve::workload::{ArrivalPlan, WorkloadKind, WorkloadSpec};

fn prompt(n: usize, seed: u32) -> Vec<u32> {
    (0..n as u32)
        .map(|i| (i.wrapping_mul(2654435761).wrapping_add(seed)) % 50_000)
        .collect()
}

/// Instance-count sweep in two fleet regimes: **hot** — the 4K prompt is
/// cached on *every* instance (the popular-system-prompt case where the
/// per-instance reference pays a full O(prompt_blocks) walk per
/// instance), and **cold** — only instance 0 caches it, so the
/// reference's other walks miss at the root and the gap honestly
/// shrinks (each walk is one hash probe, not 256). Per-instance unique
/// prompts provide tree bulk in both regimes.
fn route_sweep(ns: &[usize]) {
    const BT: usize = 16;
    let mut table = Table::new("fig15_route_sweep", &[
        "instances", "prompt_tokens", "fleet", "variant", "route_us_mean",
        "route_us_p99",
    ]);
    println!(
        "\n-- routing cost, 4K-token prompt (hot fleet = cached \
         everywhere; cold fleet = cached on one instance) --\n\
         (fused = one walk with instance bitsets; per_instance_ref = the \
         seed's one-tree-per-instance walk)"
    );
    for &n in ns {
        for fleet in ["hot", "cold"] {
            let hot = prompt(4096, 1);
            let mut gs = GlobalScheduler::new(
                PolicyKind::PromptTree,
                OperatorCostModel::paper_13b(),
                BT,
                0.0,
            );
            let mut refr = RefGlobalPromptTrees::new(BT, 0.0);
            for i in 0..n {
                let id = InstanceId(i as u32);
                gs.add_instance(id, InstanceKind::PrefillOnly);
                refr.add_instance(id, InstanceKind::PrefillOnly);
            }
            for i in 0..n {
                let id = InstanceId(i as u32);
                if fleet == "hot" || i == 0 {
                    gs.trees.record(id, &hot, 1.0);
                    refr.record(id, &hot, 1.0);
                }
                for k in 0..4u32 {
                    let p = prompt(4096, 1000 + (i as u32) * 4 + k);
                    gs.trees.record(id, &p, 1.0);
                    refr.record(id, &p, 1.0);
                }
            }
            let cost = OperatorCostModel::paper_13b();
            // The seed routing path, end to end: per-instance tree walks
            // → candidate list → Eq. 1 decision. One definition serves
            // both the sanity assert and the timing loop.
            let ref_route = |refr: &RefGlobalPromptTrees| {
                let matches = refr.match_all(&hot);
                let candidates: Vec<Candidate> = matches
                    .iter()
                    .map(|&(id, matched)| Candidate {
                        instance: id,
                        queued_tokens: 0,
                        queued_cached_ratio: 0.0,
                        matched_tokens: matched,
                        pressure: 0.0,
                    })
                    .collect();
                decide(
                    PolicyKind::PromptTree,
                    &candidates,
                    hot.len(),
                    7,
                    |x, y| cost.exec(x, y),
                )
            };
            // Sanity: both paths must route identically before timing.
            let fused_out = gs.route(&hot, 7, 2.0).unwrap();
            assert_eq!(
                fused_out.decision,
                ref_route(&refr),
                "fused and reference routing diverged at N={n} ({fleet})"
            );

            let mut fused_t = time_adaptive(80.0, 100, || {
                black_box(gs.route(&hot, 7, 2.0).unwrap());
            });
            let mut ref_t = time_adaptive(80.0, 100, || {
                black_box(ref_route(&refr));
            });
            let (fm, rm) = (fused_t.mean(), ref_t.mean());
            table.row(vec![
                n.to_string(),
                "4096".into(),
                fleet.into(),
                "fused".into(),
                format!("{fm:.2}"),
                format!("{:.2}", fused_t.p99()),
            ]);
            table.row(vec![
                n.to_string(),
                "4096".into(),
                fleet.into(),
                "per_instance_ref".into(),
                format!("{rm:.2}"),
                format!("{:.2}", ref_t.p99()),
            ]);
            println!(
                "  N={n:4} {fleet:4}: fused {fm:8.2}us  ref {rm:8.2}us  \
                 ({:.1}x)",
                rm / fm.max(1e-9)
            );
        }
    }
    table.finish();
    println!(
        "\nExpected shape: fused per-route cost near-flat in N (the walk \
         is O(prompt_blocks) + word ops); the hot-fleet reference grows \
         ~linearly — ≥5x at N=64 — while the cold-fleet gap is smaller \
         (the reference's misses are cheap): honest bounds."
    );
}

/// The paper's Fig 15 policy sweep on the discrete-event simulator.
fn policy_sim() {
    let base = WorkloadSpec::generate(WorkloadKind::Loogle, 80, 15, 2048,
                                      4096);
    println!(
        "base workload: {} sessions, {} requests",
        base.sessions.len(),
        base.total_requests()
    );
    let mut table = Table::new("fig15_scheduler", &[
        "share_ratio", "policy", "n", "cached_ratio", "ttft_mean_s",
        "ttft_p99_s", "jct_p99_s",
    ]);
    for &share in &[1usize, 2, 3, 4] {
        let mut spec = base.clone();
        for r in 1..share {
            let mut dup = base.clone();
            for s in &mut dup.sessions {
                s.id += (r * 10_000) as u64;
            }
            spec.sessions.extend(dup.sessions);
        }
        let plan = ArrivalPlan::poisson(&spec, 10.0, 15);
        for policy in [
            PolicyKind::LeastLoad,
            PolicyKind::SessionId,
            PolicyKind::PromptTree,
        ] {
            let cfg = SimConfig {
                prefill_instances: 3,
                decode_instances: 1,
                policy,
                ..Default::default()
            };
            let rep = Simulation::new(cfg, spec.clone(), &plan).run();
            let m = &rep.metrics;
            table.row(vec![
                share.to_string(),
                policy.name().into(),
                m.records.len().to_string(),
                format!("{:.3}", m.mean_cached_ratio()),
                format!("{:.4}", m.ttft().mean),
                format!("{:.4}", m.ttft().p99),
                format!("{:.4}", m.jct().p99),
            ]);
        }
    }
    table.finish();
    println!(
        "\nExpected shape (paper Fig 15): prompt-tree >= session-id >= \
         least-load on P99 TTFT; the prompt-tree advantage grows with \
         share ratio (only it can see inter-session sharing) — the paper \
         reports 59% P99 TTFT improvement over intra-session scheduling."
    );
}

fn main() {
    let mode = std::env::var("MEMSERVE_FIG15_MODE").unwrap_or_default();
    let ns: Vec<usize> = std::env::var("MEMSERVE_FIG15_N")
        .ok()
        .map(|s| {
            s.split(',')
                .filter_map(|x| x.trim().parse().ok())
                .collect::<Vec<usize>>()
        })
        .filter(|v| !v.is_empty())
        .unwrap_or_else(|| vec![4, 16, 64, 256]);
    if mode != "sim" {
        route_sweep(&ns);
    }
    if mode != "sweep" {
        policy_sim();
    }
}
