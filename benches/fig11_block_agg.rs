//! Fig 11 reproduction: network & memory-layout optimization study.
//! Transfers the KV of a 2048-token prompt (paper setup) through the
//! link model under (left) original discrete layout vs aggregated
//! layout × threads/communicators, and (right) varied NCCL buffer sizes
//! with their HBM cost.

use memserve::mempool::{BlockGeometry, TransferMode};
use memserve::net::LinkModel;
use memserve::util::bench::Table;

fn geom(aggregated: bool) -> BlockGeometry {
    // Paper-scale model (13B-class: 40 layers) — the call-count ratio
    // 2·L is what drives the figure.
    BlockGeometry {
        block_tokens: 16,
        layers: 40,
        n_heads: 40,
        head_dim: 128,
        aggregated,
    }
}

fn main() {
    let tokens = 2048;
    let bytes =
        TransferMode::ByRequest.network_bytes(&geom(false), tokens);
    println!(
        "payload: {} tokens of KV = {:.1} MB",
        tokens,
        bytes as f64 / 1e6
    );

    // ---- Left: layout × communicators ----
    let mut t = Table::new("fig11_layout_comms", &[
        "layout", "communicators", "calls", "time_ms", "speedup_vs_disc_c1",
    ]);
    let calls_disc =
        TransferMode::ByRequest.network_calls(&geom(false), tokens);
    let calls_agg =
        TransferMode::ByRequestAgg.network_calls(&geom(true), tokens);
    let mut base = None;
    for &comms in &[1usize, 2, 4, 8] {
        for (layout, calls) in
            [("Original", calls_disc), ("Agg_Block", calls_agg)]
        {
            let link = LinkModel {
                communicators: comms,
                ..LinkModel::default()
            };
            let time = link.transfer_seconds(bytes, calls, false, false);
            if base.is_none() {
                base = Some(time);
            }
            t.row(vec![
                layout.into(),
                comms.to_string(),
                calls.to_string(),
                format!("{:.3}", time * 1e3),
                format!("{:.1}x", base.unwrap() / time),
            ]);
        }
    }
    t.finish();

    // ---- Right: buffer size → perf + HBM usage ----
    let mut t2 = Table::new("fig11_buffer_hbm", &[
        "buffer_MB", "communicators", "agg_time_ms", "disc_time_ms",
        "hbm_MB",
    ]);
    for &buf_mb in &[1.0f64, 4.0, 16.0, 64.0] {
        for &comms in &[1usize, 4] {
            let link = LinkModel {
                communicators: comms,
                buffer_bytes: (buf_mb * 1e6) as usize,
                ..LinkModel::default()
            };
            let t_agg = link.transfer_seconds(bytes, calls_agg, false, false);
            let t_disc =
                link.transfer_seconds(bytes, calls_disc, false, false);
            t2.row(vec![
                format!("{buf_mb}"),
                comms.to_string(),
                format!("{:.3}", t_agg * 1e3),
                format!("{:.3}", t_disc * 1e3),
                format!("{:.0}", link.hbm_buffer_bytes() as f64 / 1e6),
            ]);
        }
    }
    t2.finish();
    println!(
        "\nExpected shape (paper Fig 11): aggregation beats the discrete \
         layout by a large margin; with small blocks more communicators \
         help but consume HBM; with aggregation one communicator is \
         enough; bigger buffers help until the payload fits."
    );
}
