//! Fig 12 reproduction: by-layer vs by-request vs by-request-agg KV
//! transfer under increasing request rate, on a 1P1D cluster running the
//! paper's fixed 1024-prompt / 32-decode workload.

use memserve::engine::DisaggMilestone;
use memserve::mempool::TransferMode;
use memserve::sim::{SimConfig, Simulation};
use memserve::util::bench::Table;
use memserve::util::rng::Rng;
use memserve::workload::{ArrivalPlan, SessionSpec, TurnSpec, WorkloadKind,
                         WorkloadSpec};

/// The paper's microbenchmark workload: every request has a unique
/// 1024-token prompt and decodes exactly 32 tokens (no cache reuse — the
/// point is the transfer path).
fn fixed_workload(n: usize, seed: u64) -> WorkloadSpec {
    let mut rng = Rng::new(seed);
    let sessions = (0..n)
        .map(|i| SessionSpec {
            id: i as u64,
            shared_prefix: vec![],
            turns: vec![TurnSpec {
                user_tokens: (0..1024)
                    .map(|_| rng.below(40000) as u32)
                    .collect(),
                target_gen: 32,
            }],
        })
        .collect();
    WorkloadSpec {
        kind: WorkloadKind::ShareGpt,
        sessions,
        seed,
    }
}

fn main() {
    let spec = fixed_workload(150, 3);
    let mut table = Table::new("fig12_transfer_mode", &[
        "mode", "rate_req_s", "jct_mean_s", "jct_p99_s", "ttst_mean_s",
        "wire_calls", "wire_busy_s",
    ]);
    for &rate in &[1.0f64, 2.0, 4.0, 8.0, 16.0] {
        let plan = ArrivalPlan::poisson(&spec, rate, 3);
        for mode in [
            TransferMode::ByLayer,
            TransferMode::ByRequest,
            TransferMode::ByRequestAgg,
        ] {
            // Paper testbed link: NVLink-class bandwidth, 2 NCCL
            // communicators (Fig 11's sweet spot for discrete blocks).
            let link = memserve::net::LinkModel {
                bandwidth: 400e9,
                communicators: 2,
                ..Default::default()
            };
            let cfg = SimConfig {
                prefill_instances: 1,
                decode_instances: 1,
                caching: false,
                milestone: DisaggMilestone::PdBasic,
                transfer_mode: mode,
                link,
                ..Default::default()
            };
            let rep = Simulation::new(cfg, spec.clone(), &plan).run();
            let m = &rep.metrics;
            // Time-to-second-token ≈ first decode iteration after the KV
            // lands: approximate as (completion-first)/31 + transfer tail
            // — report TPOT as the TTST proxy the paper plots.
            table.row(vec![
                mode.name().into(),
                format!("{rate}"),
                format!("{:.3}", m.jct().mean),
                format!("{:.3}", m.jct().p99),
                format!("{:.4}", m.tpot().mean),
                rep.wire_calls.to_string(),
                format!("{:.2}", rep.wire_seconds),
            ]);
        }
    }
    table.finish();
    println!(
        "\nExpected shape (paper Fig 12): at low rate by-layer wins \
         (compute/communication overlap); as rate grows the per-call \
         overhead of the discrete layout bites and by-req-agg takes over."
    );
}
