//! Fig 13 reproduction: the context-caching cost model study. All four
//! panels plot TTFT *improvement over no caching* against cached ratio:
//!   (a) by prompt length, (b) by batch size, (c) by block size,
//!   (d) by cached-KV location (HBM vs DRAM — swap-in cost).
//!
//! Uses the paper-scale operator-level cost model (validated against the
//! real runtime in fig14) plus the link/swap model for panel (d).

use memserve::net::LinkModel;
use memserve::scheduler::cost_model::OperatorCostModel;
use memserve::util::bench::Table;

fn improvement(t_base: f64, t_cached: f64) -> f64 {
    100.0 * (t_base - t_cached) / t_base
}

fn main() {
    let m = OperatorCostModel::paper_13b();
    let ratios = [0.0f64, 0.25, 0.5, 0.75, 0.9];

    // ---- (a) prompt length ----
    let mut ta = Table::new("fig13a_prompt_len", &[
        "prompt_len", "y=0.25", "y=0.5", "y=0.75", "y=0.9",
    ]);
    for &x in &[512usize, 1024, 2048, 4096] {
        let base = m.exec(x, 0.0);
        let mut row = vec![x.to_string()];
        for &y in &ratios[1..] {
            row.push(format!("{:.1}%", improvement(base, m.exec(x, y))));
        }
        ta.row(row);
    }
    ta.finish();

    // ---- (b) batch size (batch translates to prompt length: the cost
    // model is applied to the batch's summed tokens — paper §5.3.1) ----
    let mut tb = Table::new("fig13b_batch_size", &[
        "batch", "y=0.25", "y=0.5", "y=0.75", "y=0.9",
    ]);
    let per_prompt = 1024usize;
    for &b in &[1usize, 2, 4, 8] {
        let x = per_prompt * b;
        let base = m.exec(x, 0.0);
        let mut row = vec![b.to_string()];
        for &y in &ratios[1..] {
            row.push(format!("{:.1}%", improvement(base, m.exec(x, y))));
        }
        tb.row(row);
    }
    tb.finish();

    // ---- (c) block size: caching granularity rounds the usable cached
    // tokens DOWN to a block boundary, so large blocks waste tail hits ——
    let mut tc = Table::new("fig13c_block_size", &[
        "block_tokens", "y=0.25", "y=0.5", "y=0.75", "y=0.9",
    ]);
    let x = 2048usize;
    for &bt in &[8usize, 16, 32, 64, 128] {
        let base = m.exec(x, 0.0);
        let mut row = vec![bt.to_string()];
        for &y in &ratios[1..] {
            let usable = ((x as f64 * y) as usize) / bt * bt;
            let y_eff = usable as f64 / x as f64;
            row.push(format!(
                "{:.1}%",
                improvement(base, m.exec(x, y_eff))
            ));
        }
        tc.row(row);
    }
    tc.finish();

    // ---- (d) cached location: DRAM-resident cache pays swap-in over
    // PCIe-class bandwidth before prefill can use it ----
    let link = LinkModel::default();
    let bytes_per_token = 2 * 40 * 40 * 128 * 2; // 13B-ish KV bytes/token
    let mut td = Table::new("fig13d_cached_location", &[
        "prompt_len", "ratio", "hbm_improvement", "dram_improvement",
    ]);
    for &x in &[1024usize, 4096] {
        for &y in &[0.25f64, 0.5, 0.75, 0.9] {
            let base = m.exec(x, 0.0);
            let hbm = m.exec(x, y);
            let cached_tokens = (x as f64 * y) as usize;
            let swap_bytes = cached_tokens * bytes_per_token;
            // Swap-in: one call per 16-token block over the DRAM path.
            let swap = link.transfer_seconds(
                swap_bytes,
                cached_tokens / 16,
                true,
                false,
            );
            let dram = hbm + swap;
            td.row(vec![
                x.to_string(),
                format!("{y:.2}"),
                format!("{:.1}%", improvement(base, hbm)),
                format!("{:.1}%", improvement(base, dram)),
            ]);
        }
    }
    td.finish();
    println!(
        "\nExpected shape (paper Fig 13): improvement rises with cached \
         ratio; longer prompts gain more; batch size acts like prompt \
         length; block size barely matters until very large; DRAM-located \
         cache still wins once the ratio crosses a threshold."
    );
}
