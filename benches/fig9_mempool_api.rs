//! Fig 9 reproduction: MemPool API microbenchmarks on the *real*
//! materialized pool.
//!   (a) memory APIs (alloc_mem/free_mem) vs number of blocks;
//!   (b) index APIs (insert/match) vs cached ratio and block count.
//!
//! Paper reference points: ~800 ns per block for memory APIs; <= 0.7 ms
//! to insert a 4K-token prompt (256 blocks); latency ~flat in cached
//! ratio.

use memserve::mempool::{BlockGeometry, InstanceId, MemPool, Tier};
use memserve::util::bench::{black_box, time_adaptive, Table};

fn geom() -> BlockGeometry {
    BlockGeometry {
        block_tokens: 16,
        layers: 4,
        n_heads: 8,
        head_dim: 32,
        aggregated: true,
    }
}

fn pool(blocks: usize) -> MemPool {
    MemPool::new(InstanceId(0), geom(), blocks, blocks, 0.0, true)
}

fn toks(n: usize, seed: u32) -> Vec<u32> {
    (0..n as u32).map(|i| i.wrapping_mul(7).wrapping_add(seed)).collect()
}

fn main() {
    // ---- (a) memory APIs ----
    let mut t_mem = Table::new("fig9a_memory_apis", &[
        "blocks", "alloc_us_mean", "alloc_us_p99", "free_us_mean",
        "ns_per_block",
    ]);
    for &n in &[1usize, 4, 16, 64, 256] {
        let mut p = pool(512);
        let alloc = time_adaptive(30.0, 50, || {
            let a = p.alloc_mem(n, Tier::Hbm).unwrap();
            black_box(&a);
            p.free_mem(&a).unwrap();
        });
        // Split alloc vs free: measure free by pre-allocating.
        let mut p2 = pool(512);
        let free = time_adaptive(30.0, 50, || {
            let a = p2.alloc_mem(n, Tier::Hbm).unwrap();
            p2.free_mem(black_box(&a)).unwrap();
        });
        let mut alloc = alloc;
        let mut free = free;
        t_mem.row(vec![
            n.to_string(),
            format!("{:.2}", alloc.mean()),
            format!("{:.2}", alloc.p99()),
            format!("{:.2}", free.mean()),
            format!("{:.0}", alloc.mean() * 1000.0 / n as f64),
        ]);
    }
    t_mem.finish();

    // ---- (b) index APIs ----
    let mut t_idx = Table::new("fig9b_index_apis", &[
        "blocks", "tokens", "cached_ratio", "insert_us", "match_us",
    ]);
    for &blocks in &[16usize, 64, 256] {
        let tokens = blocks * 16;
        for &ratio in &[0.0f64, 0.5, 1.0] {
            // Pre-populate the index with `ratio` of the prompt.
            let cached_tokens = (tokens as f64 * ratio) as usize / 16 * 16;
            let seq = toks(tokens, 1);
            // insert timing: fresh pool each iteration batch; amortize by
            // deleting after insert.
            let mut p = pool(blocks * 4 + 64);
            if cached_tokens > 0 {
                let a = p.alloc_mem(cached_tokens / 16, Tier::Hbm).unwrap();
                p.insert(
                    &seq[..cached_tokens],
                    a.into_iter().map(|x| vec![x]).collect(),
                    0.0,
                )
                .unwrap();
            }
            let mut insert_s = time_adaptive(30.0, 30, || {
                let need = blocks;
                let a = p.alloc_mem(need, Tier::Hbm).unwrap();
                let groups: Vec<_> =
                    a.iter().map(|&x| vec![x]).collect();
                p.insert(&seq, groups, 1.0).unwrap();
                // Remove the un-cached tail again so the next iteration
                // re-inserts the same amount of fresh data.
                if cached_tokens < tokens {
                    let freed =
                        p.delete(&seq[..]).unwrap();
                    black_box(freed);
                    if cached_tokens > 0 {
                        let a2 = p
                            .alloc_mem(cached_tokens / 16, Tier::Hbm)
                            .unwrap();
                        p.insert(
                            &seq[..cached_tokens],
                            a2.into_iter().map(|x| vec![x]).collect(),
                            0.0,
                        )
                        .unwrap();
                    }
                }
            });
            let mut match_s = time_adaptive(30.0, 100, || {
                black_box(p.match_prefix(&seq, 2.0));
            });
            t_idx.row(vec![
                blocks.to_string(),
                tokens.to_string(),
                format!("{ratio:.1}"),
                format!("{:.2}", insert_s.mean()),
                format!("{:.2}", match_s.mean()),
            ]);
        }
    }
    t_idx.finish();
    println!(
        "\nExpected shape (paper Fig 9): memory-API latency linear in \
         block count (~sub-µs/block); insert of a 4K-token prompt (256 \
         blocks) well under 0.7 ms; match latency ~flat in cached ratio."
    );
}
