//! Hot-path performance harness (EXPERIMENTS.md §Perf).
//!
//! Measures the serving-critical operations at each layer and quantifies
//! the designed-in optimizations against their naive baselines:
//!   L3a  decode loop: device-resident state feedback vs naive
//!        re-upload-KV-every-step;
//!   L3b  paged-KV gather/scatter throughput, aggregated vs discrete;
//!   L3c  router decision + MemPool match at 4K-token prompts (must be
//!        µs-scale — far below the ms-scale compute, i.e. L3 is not the
//!        bottleneck, as the paper requires);
//!   L2   prefill bucket compute scaling (PJRT, per bucket).
//!
//! Self-skips without artifacts.

use std::sync::Arc;

use memserve::engine::kv;
use memserve::mempool::{BlockGeometry, InstanceId, MemPool, Tier};
use memserve::runtime::artifacts::artifacts_available;
use memserve::runtime::ModelRuntime;
use memserve::scheduler::cost_model::OperatorCostModel;
use memserve::scheduler::prompt_tree::InstanceKind;
use memserve::scheduler::router::GlobalScheduler;
use memserve::scheduler::PolicyKind;
use memserve::util::bench::{black_box, time_adaptive, Table};

fn toks(n: usize, seed: u32) -> Vec<u32> {
    (0..n as u32)
        .map(|i| (i.wrapping_mul(2654435761).wrapping_add(seed)) % 2048)
        .collect()
}

fn main() {
    if !artifacts_available("artifacts") {
        println!("[perf_hot_path skipped: run `make artifacts`]");
        return;
    }
    let rt = Arc::new(ModelRuntime::load("artifacts").unwrap());
    let meta = rt.meta.clone();
    let s = meta.n_heads * meta.head_dim;

    // ---------- L3a: decode loop, feedback vs naive re-upload ----------
    let mut t = Table::new("perf_decode_loop", &[
        "variant", "ctx", "ms_per_token", "tokens_per_s",
    ]);
    for &ctx in &[64usize, 256] {
        let prompt = toks(ctx / 2, 1);
        let p = rt.prefill(&prompt, None, 0).unwrap();
        let mut kv0 = vec![0f32; meta.layers * 2 * ctx * s];
        for l in 0..meta.layers {
            for h in 0..2 {
                for tk in 0..prompt.len() {
                    let src = ((l * 2 + h) * p.bucket_n + tk) * s;
                    let dst = ((l * 2 + h) * ctx + tk) * s;
                    kv0[dst..dst + s]
                        .copy_from_slice(&p.new_kv[src..src + s]);
                }
            }
        }
        // Optimized: one session, state stays on device.
        let steps = 24usize;
        let mut sess = rt.decode_start(&kv0, ctx, prompt.len()).unwrap();
        let t0 = std::time::Instant::now();
        for i in 0..steps {
            black_box(rt.decode_step(&mut sess, (i % 100) as u32).unwrap());
        }
        let per_opt = t0.elapsed().as_secs_f64() / steps as f64;
        // Naive baseline: KV round-trips through the host every step
        // (decode_start + one step + decode_kv download).
        let t0 = std::time::Instant::now();
        let mut kv_host = kv0.clone();
        let mut pos = prompt.len();
        for i in 0..steps {
            let mut s2 = rt.decode_start(&kv_host, ctx, pos).unwrap();
            black_box(rt.decode_step(&mut s2, (i % 100) as u32).unwrap());
            kv_host = rt.decode_kv(&mut s2).unwrap();
            pos += 1;
        }
        let per_naive = t0.elapsed().as_secs_f64() / steps as f64;
        for (name, per) in
            [("naive_reupload", per_naive), ("state_feedback", per_opt)]
        {
            t.row(vec![
                name.into(),
                ctx.to_string(),
                format!("{:.3}", per * 1e3),
                format!("{:.0}", 1.0 / per),
            ]);
        }
    }
    t.finish();

    // ---------- L3b: paged-KV gather/scatter throughput ----------
    let mut t2 = Table::new("perf_kv_paging", &[
        "layout", "op", "tokens", "GB_per_s",
    ]);
    for aggregated in [true, false] {
        let geom = BlockGeometry {
            block_tokens: 16,
            layers: meta.layers,
            n_heads: meta.n_heads,
            head_dim: meta.head_dim,
            aggregated,
        };
        let mut pool = MemPool::new(InstanceId(0), geom, 256, 0, 0.0, true);
        let n_tokens = 256usize;
        let kv: Vec<f32> =
            (0..geom.layers * 2 * n_tokens * s).map(|i| i as f32).collect();
        let bytes = (kv.len() * 4) as f64;
        let mut scatter_groups = None;
        let mut sc = time_adaptive(80.0, 20, || {
            let g = kv::scatter_new_kv(&mut pool, &kv, n_tokens, n_tokens,
                                       0.0)
                .unwrap();
            if let Some(old) = scatter_groups.replace(g) {
                pool.free_mem(old.flat()).unwrap();
            }
        });
        let groups = scatter_groups.unwrap();
        let mut ga = time_adaptive(80.0, 20, || {
            black_box(kv::gather_to_buffer(&pool, &groups, n_tokens)
                .unwrap());
        });
        let layout = if aggregated { "aggregated" } else { "discrete" };
        t2.row(vec![
            layout.into(),
            "scatter".into(),
            n_tokens.to_string(),
            format!("{:.2}", bytes / (sc.mean() * 1e-6) / 1e9),
        ]);
        t2.row(vec![
            layout.into(),
            "gather".into(),
            n_tokens.to_string(),
            format!("{:.2}", bytes / (ga.mean() * 1e-6) / 1e9),
        ]);
    }
    t2.finish();

    // ---------- L3c: router + index on the request path ----------
    let mut gs = GlobalScheduler::new(
        PolicyKind::PromptTree,
        OperatorCostModel::paper_13b(),
        16,
        300.0,
    );
    for i in 0..3 {
        gs.add_instance(InstanceId(i), InstanceKind::PrefillOnly);
    }
    let prompt4k = toks(4096, 9);
    gs.record_cached(InstanceId(1), &prompt4k[..2048], 1.0);
    let mut route_t = time_adaptive(60.0, 200, || {
        black_box(gs.route(&prompt4k, 7, 2.0).unwrap());
    });
    let mut pool = MemPool::new(
        InstanceId(0),
        BlockGeometry {
            block_tokens: 16,
            layers: meta.layers,
            n_heads: meta.n_heads,
            head_dim: meta.head_dim,
            aggregated: true,
        },
        512,
        0,
        0.0,
        false,
    );
    let a = pool.alloc_mem(256, Tier::Hbm).unwrap();
    pool.insert(&prompt4k, a.into_iter().map(|x| vec![x]).collect(), 0.0)
        .unwrap();
    let mut match_t = time_adaptive(60.0, 200, || {
        black_box(pool.match_prefix(&prompt4k, 1.0));
    });
    let mut t3 = Table::new("perf_request_path", &[
        "op", "us_mean", "us_p99",
    ]);
    t3.row(vec![
        "gs_route_4k_3inst".into(),
        format!("{:.1}", route_t.mean()),
        format!("{:.1}", route_t.p99()),
    ]);
    t3.row(vec![
        "pool_match_4k".into(),
        format!("{:.1}", match_t.mean()),
        format!("{:.1}", match_t.p99()),
    ]);
    t3.finish();

    // ---------- L2: prefill compute per bucket ----------
    let mut t4 = Table::new("perf_prefill_buckets", &[
        "bucket_n", "ms", "us_per_token",
    ]);
    for &n in &[16usize, 64, 256] {
        let prompt = toks(n, 3);
        let mut pf = time_adaptive(200.0, 5, || {
            black_box(rt.prefill(&prompt, None, 0).unwrap());
        });
        t4.row(vec![
            n.to_string(),
            format!("{:.2}", pf.mean() / 1e3),
            format!("{:.1}", pf.mean() / n as f64),
        ]);
    }
    t4.finish();
}
