//! Fig 18 (repo-original): fault injection + self-healing (ISSUE 6).
//!
//! Part 1 (`fig18_handshake`): a request/ack micro-protocol with the
//! shape of the KV-migration handshake — per-attempt timeout, capped
//! exponential backoff, idempotent receiver (dedupe by id, always
//! re-ack) — over a [`Fabric`] carrying a seeded [`FaultPlan`]. Sweeps
//! drop ∈ {0,5,10,20}% with duplication and reordering always on;
//! asserts **zero lost requests** at every rate and reports the retry
//! cost plus the fabric's dropped/duplicated/reordered counters.
//!
//! Part 2 (`fig18_blackout`): the discrete-event simulator with the
//! GS delta-replication stream subjected to the same drop sweep
//! (`replication_drop`) and a scripted mid-trace GS shard failover.
//! The transport's gap repair + retransmits + pre-promotion catch-up
//! must make the whole trace — every placement and cached-token count
//! — **identical** to the lossless-replication run (divergent = 0).
//!
//! Part 3 (`fig18_live`): the live cluster (requires `make artifacts`;
//! self-skips otherwise, like the server integration tests). Lossy
//! leader<->follower links while serving, a drain (the 3-step
//! migration handshake under loss), then a GS shard crash behind a
//! directed partition: heartbeat-miss detection within the
//! `heartbeat_misses x heartbeat_ms` window, degraded load-only
//! routing that **keeps serving during the blackout**, promotion
//! with capped backoff once the partition heals, and replication acks
//! converging to the log head at quiesce.
//!
//! Env knobs (used by the CI smoke job):
//! * `MEMSERVE_FIG18_MODE` — `handshake`, `blackout`, `live`,
//!   anything else/unset runs all (part 3 self-skips sans artifacts);
//! * `MEMSERVE_FIG18_DROP` — comma-separated drop percentages
//!   (default `0,5,10,20`);
//! * `MEMSERVE_FIG18_S` — GS shard count for part 2 (default `2`).

use std::collections::HashSet;
use std::sync::Arc;
use std::time::{Duration, Instant};

use memserve::config::Config;
use memserve::engine::{DisaggMilestone, SamplingParams};
use memserve::mempool::InstanceId;
use memserve::net::{Fabric, FaultPlan, LinkFaults, LinkModel, WireCost};
use memserve::runtime::artifacts::artifacts_available;
use memserve::runtime::ModelRuntime;
use memserve::scheduler::prompt_tree::InstanceKind;
use memserve::server::{ServeCluster, ServeOptions};
use memserve::sim::{FleetEvent, FleetOp, SimConfig, Simulation};
use memserve::util::bench::Table;
use memserve::workload::{ArrivalPlan, WorkloadKind, WorkloadSpec};

// ---------------------------------------------------------------------
// Part 1: retry/backoff handshake over a faulty fabric.
// ---------------------------------------------------------------------

#[derive(Clone, Debug)]
enum PingMsg {
    Req { id: u64 },
    Ack { id: u64 },
}

impl WireCost for PingMsg {
    fn wire_cost(&self) -> Option<(usize, usize, bool, bool)> {
        None // control-plane only
    }
}

const CLIENT: InstanceId = InstanceId(0);
const SERVER: InstanceId = InstanceId(1);
/// Sentinel id that tells the server thread to exit.
const STOP: u64 = u64::MAX;

/// Run N requests through the lossy link; every request retries with a
/// per-attempt timeout and capped exponential backoff until acked.
/// Returns (retries, unique requests the server landed, the fabric).
fn handshake_run(drop: f64, n: u64) -> (u64, usize, Fabric<PingMsg>) {
    let fab: Fabric<PingMsg> = Fabric::new(LinkModel::default(), false);
    let client_ep = fab.attach(CLIENT);
    let server_ep = fab.attach(SERVER);
    let mut plan = FaultPlan::new(0xF18 + (drop * 100.0) as u64);
    plan.set_default(LinkFaults {
        drop,
        duplicate: 0.05,
        reorder: 0.10,
        jitter_s: 0.0,
    });
    fab.set_fault_plan(plan);

    // Idempotent server: dedupe by id, but ALWAYS re-ack — a lost ack
    // must be repairable by the client's retransmit.
    let sfab = fab.clone();
    let server = std::thread::spawn(move || {
        let mut seen: HashSet<u64> = HashSet::new();
        while let Some((_, msg)) = server_ep.recv() {
            match msg {
                PingMsg::Req { id } if id == STOP => break,
                PingMsg::Req { id } => {
                    seen.insert(id);
                    let _ = sfab.send(SERVER, CLIENT, PingMsg::Ack { id });
                }
                PingMsg::Ack { .. } => {}
            }
        }
        seen.len()
    });

    const ATTEMPT_TIMEOUT: Duration = Duration::from_millis(8);
    const BACKOFF_BASE: Duration = Duration::from_millis(2);
    const BACKOFF_CAP: Duration = Duration::from_millis(32);
    const MAX_ATTEMPTS: u32 = 64;
    let mut retries = 0u64;
    for id in 0..n {
        let mut attempt = 0u32;
        'req: loop {
            assert!(
                attempt < MAX_ATTEMPTS,
                "request {id} lost after {attempt} attempts (drop={drop})"
            );
            fab.send(CLIENT, SERVER, PingMsg::Req { id }).unwrap();
            let deadline = Instant::now() + ATTEMPT_TIMEOUT;
            loop {
                let left = deadline.saturating_duration_since(Instant::now());
                match client_ep.recv_timeout(left.max(Duration::from_micros(1)))
                {
                    Ok((_, PingMsg::Ack { id: got })) if got == id => {
                        break 'req;
                    }
                    Ok(_) => continue, // stale/duplicate ack: ignore
                    Err(_) => break,   // attempt timed out
                }
            }
            retries += 1;
            let backoff = BACKOFF_BASE * 2u32.pow(attempt.min(4));
            std::thread::sleep(backoff.min(BACKOFF_CAP));
            attempt += 1;
        }
    }
    // Quiesce: lift the plan (flushes holdbacks) and stop the server.
    fab.clear_fault_plan();
    fab.send(CLIENT, SERVER, PingMsg::Req { id: STOP }).unwrap();
    let landed = server.join().unwrap();
    (retries, landed, fab)
}

fn handshake_sweep(drops_pct: &[u32]) {
    let mut table = Table::new("fig18_handshake", &[
        "drop_pct",
        "requests",
        "landed",
        "retries",
        "net_dropped",
        "net_duplicated",
        "net_reordered",
    ]);
    println!(
        "\n-- retry/backoff handshake under drop+dup+reorder: every \
         request must land exactly once (idempotent receiver) despite \
         silent losses --"
    );
    const N: u64 = 160;
    for &d in drops_pct {
        let p = d as f64 / 100.0;
        let (retries, landed, fab) = handshake_run(p, N);
        assert_eq!(
            landed, N as usize,
            "server landed {landed} unique requests, expected {N} \
             (drop={d}%)"
        );
        let s = fab.stats();
        if d > 0 {
            assert!(s.dropped > 0, "drop={d}% never dropped a message");
        }
        table.row(vec![
            d.to_string(),
            N.to_string(),
            landed.to_string(),
            retries.to_string(),
            s.dropped.to_string(),
            s.duplicated.to_string(),
            s.reordered.to_string(),
        ]);
        println!(
            "  drop={d:2}%: {landed}/{N} landed, {retries:3} retries \
             (net: {} dropped, {} duplicated, {} reordered)",
            s.dropped, s.duplicated, s.reordered
        );
    }
    table.finish();
    println!(
        "\nExpected shape: landed = requests at every rate (zero loss); \
         retries grow with the drop rate — the price of self-healing, \
         paid in retransmits, never in lost work."
    );
}

// ---------------------------------------------------------------------
// Part 2: lossy GS replication + scripted shard failover in the
// discrete-event simulator.
// ---------------------------------------------------------------------

fn blackout_sweep(drops_pct: &[u32], shards: usize) {
    let mut table = Table::new("fig18_blackout", &[
        "drop_pct",
        "shards",
        "requests",
        "completed",
        "gs_failovers",
        "divergent",
    ]);
    println!(
        "\n-- lossy delta replication + mid-trace GS shard failover: \
         the recovered trace must be identical to the lossless run --"
    );
    let spec =
        WorkloadSpec::generate(WorkloadKind::Loogle, 40, 35, 2048, 4096);
    let plan = ArrivalPlan::poisson(&spec, 4.0, 35);
    let total = spec.total_requests();
    let mk = |p: f64| SimConfig {
        prefill_instances: 3,
        decode_instances: 2,
        colocated_instances: 0,
        caching: true,
        milestone: DisaggMilestone::PdCaching3,
        gs_shards: shards,
        gs_replicas: 2,
        replication_drop: p,
        fleet: vec![FleetEvent {
            at: 5.0,
            op: FleetOp::GsFailover { shard: Some(0) },
        }],
        ..Default::default()
    };
    let key = |rep: &memserve::sim::SimReport| {
        let mut v: Vec<_> = rep
            .metrics
            .records
            .iter()
            .map(|r| {
                (
                    r.request_id,
                    r.prefill_instance,
                    r.decode_instance,
                    r.cached_tokens,
                )
            })
            .collect();
        v.sort_unstable();
        v
    };
    let reference = Simulation::new(mk(0.0), spec.clone(), &plan).run();
    assert_eq!(reference.metrics.records.len(), total);
    assert_eq!(reference.gs_failovers, 1);
    let kref = key(&reference);
    for &d in drops_pct {
        let p = d as f64 / 100.0;
        let rep = Simulation::new(mk(p), spec.clone(), &plan).run();
        assert_eq!(
            rep.metrics.records.len(),
            total,
            "lost requests at replication drop {d}%"
        );
        assert_eq!(rep.gs_failovers, 1);
        let k = key(&rep);
        let divergent =
            k.iter().zip(&kref).filter(|(a, b)| a != b).count();
        assert_eq!(
            divergent, 0,
            "lossy replication (drop {d}%) changed the trace"
        );
        table.row(vec![
            d.to_string(),
            shards.to_string(),
            total.to_string(),
            rep.metrics.records.len().to_string(),
            rep.gs_failovers.to_string(),
            divergent.to_string(),
        ]);
        println!(
            "  drop={d:2}%: {}/{total} completed, {} failover(s), \
             {divergent} divergent placements",
            rep.metrics.records.len(),
            rep.gs_failovers
        );
    }
    table.finish();
    println!(
        "\nExpected shape: completed = requests and divergent = 0 at \
         every rate — gap repair and pre-promotion catch-up hide the \
         lossy transport entirely."
    );
}

// ---------------------------------------------------------------------
// Part 3: live cluster — heartbeat failure detection, degraded
// routing during the blackout, promotion with backoff, quiesce
// convergence. Requires `make artifacts` (self-skips otherwise).
// ---------------------------------------------------------------------

/// The leader's fabric address (`ServeCluster` control plane).
const LEADER: InstanceId = InstanceId(u32::MAX);

fn toks(n: usize, seed: u32) -> Vec<u32> {
    (0..n as u32)
        .map(|i| (i.wrapping_mul(2654435761).wrapping_add(seed)) % 2048)
        .collect()
}

fn sampling(max_new: usize) -> SamplingParams {
    SamplingParams {
        max_new_tokens: max_new,
        eos_token: u32::MAX,
        ..Default::default()
    }
}

fn live() {
    if !artifacts_available("artifacts") {
        println!("\n[skip] fig18_live: artifacts/ not built");
        return;
    }
    let mut table = Table::new("fig18_live", &[
        "phase",
        "elapsed_ms",
        "detail",
    ]);
    println!(
        "\n-- live cluster under faults: serve -> drain -> GS shard \
         crash behind a partition -> detect -> degrade -> heal -> \
         promote -> converge --"
    );
    let rt = Arc::new(ModelRuntime::load("artifacts").unwrap());
    let mut cfg = Config::default();
    cfg.cluster.prefill_instances = 2;
    cfg.cluster.decode_instances = 1;
    cfg.cluster.colocated_instances = 0;
    cfg.cluster.heartbeat_ms = 100.0;
    cfg.cluster.heartbeat_misses = 3;
    cfg.mempool.context_caching = true;
    cfg.mempool.hbm_blocks = 256;
    cfg.mempool.dram_blocks = 256;
    cfg.scheduler.gs_replicas = 2;
    cfg.scheduler.gs_shards = 2;
    let window = Duration::from_secs_f64(
        cfg.cluster.heartbeat_ms / 1e3 * cfg.cluster.heartbeat_misses as f64,
    );
    let c = ServeCluster::start(
        ServeOptions {
            config: cfg,
            milestone: DisaggMilestone::PdCaching3,
            real_sleep: false,
        },
        rt,
    )
    .unwrap();
    let t = Duration::from_secs(120);

    // Warm a prefix on a known holder, fault-free.
    let warm = toks(64, 21);
    let r = c.submit(warm.clone(), 1, sampling(4)).unwrap();
    let (g_warm, _) = c.collect(r, t).unwrap();

    // Lossy leader<->follower links (replication, heartbeats, the
    // promotion exchange); everything else stays clean.
    let followers = c.gs_follower_ids();
    assert_eq!(followers.len(), 2);
    let lossy = LinkFaults {
        drop: 0.10,
        duplicate: 0.05,
        reorder: 0.10,
        jitter_s: 0.0,
    };
    let mut plan = FaultPlan::new(0xF18);
    for &f in &followers {
        plan.set_link(LEADER, f, lossy.clone());
        plan.set_link(f, LEADER, lossy.clone());
    }
    c.install_fault_plan(plan);

    // Phase A: serve under lossy replication — zero lost requests.
    let t0 = Instant::now();
    let rids: Vec<u64> = (0..6)
        .map(|i| c.submit(toks(48, 500 + i), i as u64, sampling(3)).unwrap())
        .collect();
    for rid in rids {
        let (g, _) = c.collect(rid, t).unwrap();
        assert_eq!(g.len(), 3, "request lost under lossy replication");
    }
    table.row(vec![
        "serve_lossy".into(),
        format!("{:.0}", t0.elapsed().as_secs_f64() * 1e3),
        "6/6 collected".into(),
    ]);

    // Phase B: the 3-step migration handshake under loss — join a
    // fresh instance, drain an old one; retries + the idempotent
    // landing dedupe must deliver the cache without loss.
    let t0 = Instant::now();
    let victim = c
        .instances()
        .iter()
        .find(|(_, k)| matches!(k, InstanceKind::PrefillOnly))
        .map(|(i, _)| *i)
        .expect("a prefill instance exists");
    c.join(InstanceKind::PrefillOnly).unwrap();
    let report = c.drain(victim, t).unwrap();
    table.row(vec![
        "drain_lossy".into(),
        format!("{:.0}", t0.elapsed().as_secs_f64() * 1e3),
        format!("{} prefixes migrated", report.migrated_prefixes),
    ]);

    // Phase C: partition the leader->follower direction so the
    // promotion handshake cannot complete, then crash shard 0. The
    // detector must suspect within the miss window; the router must
    // keep serving (load-only fallback) for the whole blackout.
    let mut p = FaultPlan::new(0xF18);
    for &f in &followers {
        p.set_link(LEADER, f, lossy.clone());
        p.set_link(f, LEADER, lossy.clone());
        p.isolate(LEADER, f);
    }
    c.install_fault_plan(p);
    c.inject_gs_shard_crash(0).unwrap();
    let crash_at = Instant::now();
    let mut detect = None;
    while detect.is_none() && crash_at.elapsed() < Duration::from_secs(10) {
        if c.gs_shard_degraded(0) {
            detect = Some(crash_at.elapsed());
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    let detect = detect.expect("shard-0 crash never detected");
    assert!(
        detect >= window / 2,
        "detected in {detect:?}, before the {window:?} miss window"
    );
    assert!(
        detect <= window + Duration::from_secs(2),
        "detection took {detect:?}, bound {window:?} + sweep slack"
    );
    table.row(vec![
        "detect".into(),
        format!("{:.0}", detect.as_secs_f64() * 1e3),
        format!("window {:.0}ms", window.as_secs_f64() * 1e3),
    ]);

    // Still serving during the blackout (prompts that hash into the
    // degraded shard fall back to load-only placement).
    let t0 = Instant::now();
    assert!(c.gs_shard_degraded(0), "blackout ended prematurely");
    let rids: Vec<u64> = (0..4)
        .map(|i| c.submit(toks(40, 900 + i), i as u64, sampling(3)).unwrap())
        .collect();
    for rid in rids {
        let (g, _) = c.collect(rid, t).unwrap();
        assert_eq!(g.len(), 3, "request lost during GS blackout");
    }
    table.row(vec![
        "serve_blackout".into(),
        format!("{:.0}", t0.elapsed().as_secs_f64() * 1e3),
        "4/4 collected while degraded".into(),
    ]);

    // Heal the partition: the next promotion retry (capped backoff)
    // gets through and the Snapshot reply restores the shard.
    c.with_faults(|p| {
        for &f in &followers {
            p.heal(LEADER, f);
        }
    });
    let healed_at = Instant::now();
    while c.gs_shard_degraded(0)
        && healed_at.elapsed() < Duration::from_secs(10)
    {
        std::thread::sleep(Duration::from_millis(2));
    }
    let recover = healed_at.elapsed();
    assert!(
        !c.gs_shard_degraded(0),
        "shard 0 never recovered after the partition healed"
    );
    assert!(
        recover <= Duration::from_secs(5),
        "recovery took {recover:?} after heal (retry cap + RTT bound)"
    );
    table.row(vec![
        "promote".into(),
        format!("{:.0}", recover.as_secs_f64() * 1e3),
        "degraded flag cleared".into(),
    ]);

    // Quiesce: drop the plan, stir a few deltas so gap repair runs,
    // and require every follower ack to converge to the log head.
    c.clear_fault_plan();
    let t0 = Instant::now();
    for i in 0..3 {
        let rid = c.submit(toks(32, 1500 + i), 7, sampling(2)).unwrap();
        c.collect(rid, t).unwrap();
    }
    let mut converged = false;
    while !converged && t0.elapsed() < Duration::from_secs(15) {
        let (head, acks) = c.gs_replication_status();
        converged = !acks.is_empty() && acks.iter().all(|&(_, a)| a == head);
        if !converged {
            std::thread::sleep(Duration::from_millis(10));
        }
    }
    let (head, acks) = c.gs_replication_status();
    assert!(
        converged,
        "replicas never converged at quiesce: head={head} acks={acks:?}"
    );
    table.row(vec![
        "quiesce".into(),
        format!("{:.0}", t0.elapsed().as_secs_f64() * 1e3),
        format!("head {head}, {} acks equal", acks.len()),
    ]);

    // The warm prefix survived the whole gauntlet: same greedy output.
    let r = c.submit(warm, 1, sampling(4)).unwrap();
    let (g2, rec) = c.collect(r, t).unwrap();
    assert_eq!(g_warm, g2, "faults changed generation");
    table.row(vec![
        "rewarm".into(),
        "0".into(),
        format!("cached {} tokens", rec.cached_tokens),
    ]);
    c.shutdown();
    table.finish();
    println!(
        "\nExpected shape: detection lands just past the miss window; \
         the blackout serves every request; promotion completes within \
         one retry cap of the heal; acks converge to the head."
    );
}

fn main() {
    let mode = std::env::var("MEMSERVE_FIG18_MODE").unwrap_or_default();
    let drops: Vec<u32> = std::env::var("MEMSERVE_FIG18_DROP")
        .ok()
        .map(|s| {
            s.split(',')
                .filter_map(|x| x.trim().parse().ok())
                .collect::<Vec<u32>>()
        })
        .filter(|v| !v.is_empty())
        .unwrap_or_else(|| vec![0, 5, 10, 20]);
    let shards: usize = std::env::var("MEMSERVE_FIG18_S")
        .ok()
        .and_then(|s| s.trim().parse().ok())
        .unwrap_or(2);
    let all = !matches!(mode.as_str(), "handshake" | "blackout" | "live");
    if all || mode == "handshake" {
        handshake_sweep(&drops);
    }
    if all || mode == "blackout" {
        blackout_sweep(&drops, shards);
    }
    if all || mode == "live" {
        live();
    }
}
