//! Fig 7 reproduction: workload statistics for the three synthetic
//! traces — (a) prompt length, (b) generation length, (c) prompt:gen
//! ratio, (d) shared-prefix percentage. Prints distribution digests and
//! ASCII histograms; JSON lands in bench_results/.

use memserve::util::bench::Table;
use memserve::util::stats::Histogram;
use memserve::workload::{WorkloadKind, WorkloadSpec, WorkloadStats};

fn main() {
    let n_sessions = 400;
    let seed = 7;
    let mut table = Table::new("fig7_workloads", &[
        "workload", "requests", "prompt_mean", "prompt_p50", "gen_mean",
        "gen_p50", "ratio_mean", "shared_prefix_mean_pct",
        "shared_prefix_p50_pct",
    ]);
    for kind in WorkloadKind::all() {
        let spec =
            WorkloadSpec::generate(kind, n_sessions, seed, 2048, 4096);
        let mut st = WorkloadStats::compute(&spec);
        table.row(vec![
            kind.name().into(),
            st.requests.to_string(),
            format!("{:.0}", st.prompt_len.mean()),
            format!("{:.0}", st.prompt_len.p50()),
            format!("{:.0}", st.gen_len.mean()),
            format!("{:.0}", st.gen_len.p50()),
            format!("{:.1}", st.ratio.mean()),
            format!("{:.1}", st.shared_prefix_pct.mean()),
            format!("{:.1}", st.shared_prefix_pct.p50()),
        ]);
        // Panel (d): shared-prefix distribution as ASCII histogram.
        let mut h = Histogram::new(0.0, 100.0, 10);
        for &v in st.shared_prefix_pct.values() {
            h.push(v);
        }
        println!("\n{} shared-prefix % distribution:", kind.name());
        for line in h.ascii(40) {
            println!("  {line}");
        }
    }
    table.finish();
    println!(
        "\nExpected shape (paper Fig 7): LooGLE longest prompts + \
         shortest generations + highest prefix share; ReAct long prompts \
         with high share and longer generations; ShareGPT balanced."
    );
}
