//! Fig 10 reproduction: vanilla-vLLM-style *hash* prefix index vs
//! MemServe's radix index — prefill-side index-check cost vs prompt
//! length (no cached data, the paper's setup).
//!
//! The hash baseline mirrors vLLM 0.4's prefix caching: every block is
//! keyed by a hash of ALL tokens from the prompt start through that
//! block, so a single index check costs O(n²/bt) token hashing, which
//! blows up with prompt length. The radix walk is O(n).

use std::collections::HashMap;

use memserve::mempool::RadixIndex;
use memserve::util::bench::{black_box, time_adaptive, Table};

const BT: usize = 16;

/// vLLM-style hash-based prefix index (baseline).
struct HashPrefixIndex {
    map: HashMap<u64, u64>, // prefix hash -> block handle
}

impl HashPrefixIndex {
    fn new() -> Self {
        HashPrefixIndex {
            map: HashMap::new(),
        }
    }

    fn hash_prefix(tokens: &[u32]) -> u64 {
        // FNV over the whole prefix — recomputed per block, as the
        // original does (each block's key covers tokens [0..end)).
        let mut h: u64 = 0xcbf29ce484222325;
        for &t in tokens {
            h ^= t as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        h
    }

    fn insert(&mut self, tokens: &[u32]) {
        let blocks = tokens.len() / BT;
        for b in 1..=blocks {
            let h = Self::hash_prefix(&tokens[..b * BT]);
            self.map.entry(h).or_insert(b as u64);
        }
    }

    /// The per-request index check. vLLM computes the hash chain for
    /// EVERY block of the prompt at admission (the hashes also key block
    /// allocation), so the cost is O(n²/bt) token hashing regardless of
    /// how much actually hits.
    fn match_prefix(&self, tokens: &[u32]) -> usize {
        let blocks = tokens.len() / BT;
        let mut matched = 0;
        let mut still_matching = true;
        for b in 1..=blocks {
            // black_box: the hash is always computed in vLLM (it keys
            // allocation); don't let LLVM elide the dead-looking ones.
            let h = std::hint::black_box(Self::hash_prefix(
                &tokens[..b * BT],
            ));
            let hit = self.map.contains_key(&h);
            if still_matching && hit {
                matched = b * BT;
            } else {
                still_matching = false;
            }
        }
        matched
    }
}

fn toks(n: usize, seed: u32) -> Vec<u32> {
    (0..n as u32)
        .map(|i| i.wrapping_mul(2654435761).wrapping_add(seed) % 50000)
        .collect()
}

fn main() {
    let mut table = Table::new("fig10_index", &[
        "prompt_tokens", "hash_check_us", "radix_check_us", "speedup",
    ]);
    for &n in &[128usize, 256, 512, 1024, 2048, 4096] {
        // Cold index (paper: "no cached data"), the check still has to
        // hash/walk the whole prompt.
        let hash = HashPrefixIndex::new();
        let mut radix = RadixIndex::new(BT, 0.0);
        let prompt = toks(n, 1);
        let mut t_hash = time_adaptive(40.0, 200, || {
            black_box(hash.match_prefix(black_box(&prompt)));
        });
        let mut t_radix = time_adaptive(40.0, 200, || {
            black_box(radix.match_prefix(black_box(&prompt), 1.0));
        });
        table.row(vec![
            n.to_string(),
            format!("{:.2}", t_hash.mean()),
            format!("{:.2}", t_radix.mean()),
            format!("{:.1}x", t_hash.mean() / t_radix.mean().max(1e-9)),
        ]);
    }
    table.finish();

    // Warm-index variant: both indexes hold the full prompt.
    let mut table2 = Table::new("fig10_index_warm", &[
        "prompt_tokens", "hash_check_us", "radix_check_us", "speedup",
    ]);
    for &n in &[128usize, 512, 2048, 4096] {
        let prompt = toks(n, 2);
        let mut hash = HashPrefixIndex::new();
        hash.insert(&prompt);
        let mut radix = RadixIndex::new(BT, 0.0);
        let groups = vec![vec![]; n / BT];
        radix.insert(&prompt, &groups, 0.0);
        let mut t_hash = time_adaptive(40.0, 200, || {
            black_box(hash.match_prefix(black_box(&prompt)));
        });
        let mut t_radix = time_adaptive(40.0, 200, || {
            black_box(radix.match_prefix(black_box(&prompt), 1.0));
        });
        table2.row(vec![
            n.to_string(),
            format!("{:.2}", t_hash.mean()),
            format!("{:.2}", t_radix.mean()),
            format!("{:.1}x", t_hash.mean() / t_radix.mean().max(1e-9)),
        ]);
    }
    table2.finish();
    println!(
        "\nExpected shape (paper Fig 10): the hash check grows \
         super-linearly with prompt length (O(n²/bt) hashing) while the \
         radix walk stays near-linear — 'vanilla vLLM's hash-based \
         prefix mechanism incurs a huge overhead as the prompt length \
         increases'."
    );
}
