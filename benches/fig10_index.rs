//! Fig 10 reproduction: vanilla-vLLM-style *hash* prefix index vs
//! MemServe's radix index — prefill-side index-check cost vs prompt
//! length (no cached data, the paper's setup) — plus an
//! eviction-under-pressure study.
//!
//! The hash baseline mirrors vLLM 0.4's prefix caching: every block is
//! keyed by a hash of ALL tokens from the prompt start through that
//! block, so a single index check costs O(n²/bt) token hashing, which
//! blows up with prompt length. The radix walk is O(n).
//!
//! The third table fills an index with N single-block entries and then
//! measures sustained evict+insert churn — exactly the regime a full
//! pool lives in. The seed implementation ([`RefRadixIndex`]) scans all
//! nodes per victim (O(N) per op, O(N²) to turn the pool over); the
//! optimized index pops a lazy LRU heap (O(log N) amortized), so its
//! per-op cost must stay flat as N grows.

use std::collections::HashMap;

use memserve::mempool::{BlockAddr, InstanceId, RadixIndex, RefRadixIndex, Tier};
use memserve::util::bench::{black_box, time_adaptive, Table};

const BT: usize = 16;

/// vLLM-style hash-based prefix index (baseline).
struct HashPrefixIndex {
    map: HashMap<u64, u64>, // prefix hash -> block handle
}

impl HashPrefixIndex {
    fn new() -> Self {
        HashPrefixIndex {
            map: HashMap::new(),
        }
    }

    fn hash_prefix(tokens: &[u32]) -> u64 {
        // FNV over the whole prefix — recomputed per block, as the
        // original does (each block's key covers tokens [0..end)).
        let mut h: u64 = 0xcbf29ce484222325;
        for &t in tokens {
            h ^= t as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        h
    }

    fn insert(&mut self, tokens: &[u32]) {
        let blocks = tokens.len() / BT;
        for b in 1..=blocks {
            let h = Self::hash_prefix(&tokens[..b * BT]);
            self.map.entry(h).or_insert(b as u64);
        }
    }

    /// The per-request index check. vLLM computes the hash chain for
    /// EVERY block of the prompt at admission (the hashes also key block
    /// allocation), so the cost is O(n²/bt) token hashing regardless of
    /// how much actually hits.
    fn match_prefix(&self, tokens: &[u32]) -> usize {
        let blocks = tokens.len() / BT;
        let mut matched = 0;
        let mut still_matching = true;
        for b in 1..=blocks {
            // black_box: the hash is always computed in vLLM (it keys
            // allocation); don't let LLVM elide the dead-looking ones.
            let h = std::hint::black_box(Self::hash_prefix(
                &tokens[..b * BT],
            ));
            let hit = self.map.contains_key(&h);
            if still_matching && hit {
                matched = b * BT;
            } else {
                still_matching = false;
            }
        }
        matched
    }
}

fn toks(n: usize, seed: u32) -> Vec<u32> {
    (0..n as u32)
        .map(|i| i.wrapping_mul(2654435761).wrapping_add(seed) % 50000)
        .collect()
}

fn main() {
    let mut table = Table::new("fig10_index", &[
        "prompt_tokens", "hash_check_us", "radix_check_us", "speedup",
    ]);
    for &n in &[128usize, 256, 512, 1024, 2048, 4096] {
        // Cold index (paper: "no cached data"), the check still has to
        // hash/walk the whole prompt.
        let hash = HashPrefixIndex::new();
        let mut radix = RadixIndex::new(BT, 0.0);
        let prompt = toks(n, 1);
        let mut t_hash = time_adaptive(40.0, 200, || {
            black_box(hash.match_prefix(black_box(&prompt)));
        });
        let mut t_radix = time_adaptive(40.0, 200, || {
            black_box(radix.match_prefix(black_box(&prompt), 1.0));
        });
        table.row(vec![
            n.to_string(),
            format!("{:.2}", t_hash.mean()),
            format!("{:.2}", t_radix.mean()),
            format!("{:.1}x", t_hash.mean() / t_radix.mean().max(1e-9)),
        ]);
    }
    table.finish();

    // Warm-index variant: both indexes hold the full prompt.
    let mut table2 = Table::new("fig10_index_warm", &[
        "prompt_tokens", "hash_check_us", "radix_check_us", "speedup",
    ]);
    for &n in &[128usize, 512, 2048, 4096] {
        let prompt = toks(n, 2);
        let mut hash = HashPrefixIndex::new();
        hash.insert(&prompt);
        let mut radix = RadixIndex::new(BT, 0.0);
        radix.insert_unaddressed(&prompt, 0.0);
        let mut t_hash = time_adaptive(40.0, 200, || {
            black_box(hash.match_prefix(black_box(&prompt)));
        });
        let mut t_radix = time_adaptive(40.0, 200, || {
            black_box(radix.match_prefix(black_box(&prompt), 1.0));
        });
        table2.row(vec![
            n.to_string(),
            format!("{:.2}", t_hash.mean()),
            format!("{:.2}", t_radix.mean()),
            format!("{:.1}x", t_hash.mean() / t_radix.mean().max(1e-9)),
        ]);
    }
    table2.finish();

    // Eviction under pressure: fill to N entries, then sustained
    // evict(1)+insert(1) churn at steady state. Victim selection must
    // not scale with node count (seed: O(N) scan per victim).
    let mut table3 = Table::new("fig10_evict_churn", &[
        "nodes", "seed_scan_us", "radix_heap_us", "speedup",
    ]);
    for &n_nodes in &[256usize, 1024, 4096, 16384] {
        let mut seed_idx = RefRadixIndex::new(BT, 0.0);
        let mut radix = RadixIndex::new(BT, 0.0);
        for i in 0..n_nodes as u64 {
            let p = churn_prompt(i);
            let g = vec![vec![churn_addr(i as u32)]];
            seed_idx.insert(&p, &g, i as f64);
            radix.insert(&p, &g, i as f64);
        }
        let mut next_prompt = n_nodes as u64;
        let mut next_addr = n_nodes as u32;
        let mut now = n_nodes as f64;
        let mut t_seed = time_adaptive(40.0, 50, || {
            black_box(seed_idx.evict_lru(1));
            now += 1.0;
            next_prompt += 1;
            next_addr = next_addr.wrapping_add(1);
            seed_idx.insert(
                &churn_prompt(next_prompt),
                &[vec![churn_addr(next_addr)]],
                now,
            );
        });
        let mut t_radix = time_adaptive(40.0, 50, || {
            black_box(radix.evict_lru(1));
            now += 1.0;
            next_prompt += 1;
            next_addr = next_addr.wrapping_add(1);
            radix.insert(
                &churn_prompt(next_prompt),
                &[vec![churn_addr(next_addr)]],
                now,
            );
        });
        table3.row(vec![
            n_nodes.to_string(),
            format!("{:.2}", t_seed.mean()),
            format!("{:.2}", t_radix.mean()),
            format!("{:.1}x", t_seed.mean() / t_radix.mean().max(1e-9)),
        ]);
    }
    table3.finish();

    println!(
        "\nExpected shape (paper Fig 10): the hash check grows \
         super-linearly with prompt length (O(n²/bt) hashing) while the \
         radix walk stays near-linear — 'vanilla vLLM's hash-based \
         prefix mechanism incurs a huge overhead as the prompt length \
         increases'. In the churn table, seed_scan_us grows linearly \
         with the node count while radix_heap_us stays flat — the \
         O(N)-per-victim scan vs the O(log N) lazy-heap pop."
    );
}

/// Unique single-block prompt for churn entry `i` (the first token is a
/// bijection of `i`, so first blocks never collide).
fn churn_prompt(i: u64) -> Vec<u32> {
    let base = (i as u32).wrapping_mul(2654435761);
    (0..BT as u32).map(|t| base.wrapping_add(t)).collect()
}

fn churn_addr(i: u32) -> BlockAddr {
    BlockAddr::new(InstanceId(0), Tier::Hbm, i)
}
