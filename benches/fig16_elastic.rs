//! Elasticity: what survives a scale-down (and what a scale-up buys).
//!
//! Part 1 (`fig16_prefix_survival`): drain one instance of an
//! N-instance fleet with **real pools** (materialized KV, actual block
//! copies through the 3-step transfer protocol) and measure the
//! fleet-wide hit rate on the drained instance's hot prefixes:
//! migrate-on-drain must retain ≥ 80% of it (cold tails are dropped by
//! design), while the naive decommission baseline drops to ~0%.
//!
//! Part 2 (`fig16_elastic_sim`): the discrete-event cluster under a
//! LooGLE multi-turn workload with a mid-run drain (migrate vs naive)
//! and a mid-run join — JCT/TTFT/cached-ratio for requests arriving
//! after the fleet change, with zero request loss required.
//!
//! Env knobs (used by the CI smoke job):
//! * `MEMSERVE_FIG16_MODE` — `survival` (part 1 only), `sim` (part 2
//!   only), anything else/unset runs both;
//! * `MEMSERVE_FIG16_N` — fleet size for part 1 (default 4);
//! * `MEMSERVE_FIG16_SESSIONS` — workload sessions for part 2
//!   (default 50).

use std::time::Instant;

use memserve::elastic::delta::DeltaEvent;
use memserve::elastic::executor::{migrate_prefix, MigrationOutcome};
use memserve::elastic::planner::{
    plan_migration, PlannerConfig, Recipient,
};
use memserve::mempool::{
    BlockGeometry, InstanceId, MemPool, Tier, TransferMode,
};
use memserve::scheduler::prompt_tree::InstanceKind;
use memserve::scheduler::shard::ShardedPromptTrees;
use memserve::sim::{FleetEvent, FleetOp, SimConfig, SimReport, Simulation};
use memserve::util::bench::Table;
use memserve::workload::{ArrivalPlan, WorkloadKind, WorkloadSpec};

const BT: usize = 16;

fn geom() -> BlockGeometry {
    BlockGeometry {
        block_tokens: BT,
        layers: 2,
        n_heads: 2,
        head_dim: 8,
        aggregated: true,
    }
}

fn prompt(n: usize, seed: u32) -> Vec<u32> {
    (0..n as u32)
        .map(|i| (i.wrapping_mul(2654435761).wrapping_add(seed)) % 50_000)
        .collect()
}

/// Seed `tokens` into a pool with recognizable per-block data.
fn seed_pool(pool: &mut MemPool, tokens: &[u32], fill: f32, now: f64) {
    let nb = tokens.len() / BT;
    let fpb = pool.geometry().floats_per_block();
    let addrs = pool.alloc_mem(nb, Tier::Hbm).expect("pool sized for warmup");
    for (i, &a) in addrs.iter().enumerate() {
        pool.write_block(a, &vec![fill + i as f32; fpb]).unwrap();
    }
    pool.insert(
        tokens,
        addrs.into_iter().map(|a| vec![a]).collect(),
        now,
    )
    .unwrap();
}

/// Fleet-wide best matched fraction for `tokens` (routable view).
fn best_match(tree: &mut ShardedPromptTrees, tokens: &[u32]) -> f64 {
    let mut out = vec![];
    tree.match_into(tokens, &mut out);
    out.iter()
        .map(|&(_, m)| m as f64 / tokens.len() as f64)
        .fold(0.0, f64::max)
}

struct SurvivalRun {
    retention: f64,
    outcome: MigrationOutcome,
    dropped_blocks: usize,
    plan_us: f64,
    exec_us: f64,
}

/// Build an N-instance fleet, warm instance 0 with hot + cold prefixes,
/// drain it, and measure what the fleet still hits.
fn survival_run(n: usize, migrate: bool) -> SurvivalRun {
    const HOT: usize = 8; // hot 2K-token prompts on the victim
    let now_warm = 100.0;
    let now_drain = 110.0;
    // Two prefix-range shards: the planner and the handoff path run
    // the sharded tree exactly as the live leader now does.
    let mut tree = ShardedPromptTrees::with_shards(BT, 0.0, 2);
    let mut pools: Vec<MemPool> = (0..n)
        .map(|i| {
            tree.add_instance(InstanceId(i as u32), InstanceKind::PrefillOnly);
            MemPool::new(InstanceId(i as u32), geom(), 2048, 0, 0.0, true)
        })
        .collect();
    let hot_prompts: Vec<Vec<u32>> =
        (0..HOT).map(|k| prompt(2048, k as u32)).collect();
    for (k, p) in hot_prompts.iter().enumerate() {
        seed_pool(&mut pools[0], p, (10 * k) as f32, now_warm);
        tree.record(InstanceId(0), p, now_warm);
    }
    // Cold tails on the victim: stale (stamped long before the drain)
    // and shallow — the planner must drop, not ship, these.
    for k in 0..4u32 {
        let p = prompt(512, 900 + k);
        seed_pool(&mut pools[0], &p, 0.5, 1.0);
        tree.record(InstanceId(0), &p, 1.0);
    }
    // Bulk on the survivors so recipient ranking sees real pressure.
    for i in 1..n {
        for k in 0..2u32 {
            let p = prompt(1024, 5000 + (i as u32) * 8 + k);
            seed_pool(&mut pools[i], &p, 2.0, now_warm);
            tree.record(InstanceId(i as u32), &p, now_warm);
        }
    }
    // Sanity: pre-drain, the victim serves every hot prompt.
    for p in &hot_prompts {
        assert_eq!(best_match(&mut tree, p), 1.0);
    }

    // --- Drain instance 0. ---
    tree.set_draining(InstanceId(0), true);
    let (outcome, dropped, plan_us, exec_us) = if migrate {
        let recipients: Vec<Recipient> = (1..n)
            .map(|i| Recipient {
                id: InstanceId(i as u32),
                pressure: pools[i].used_blocks(Tier::Hbm) as f64
                    / pools[i].capacity(Tier::Hbm).max(1) as f64,
            })
            .collect();
        let cfg = PlannerConfig {
            min_depth_blocks: 2,
            max_age_s: 60.0, // the t=1 cold tails age out
            max_blocks: None,
        };
        let t0 = Instant::now();
        let plan = plan_migration(
            &tree,
            InstanceId(0),
            now_drain,
            &recipients,
            &cfg,
        );
        let plan_us = t0.elapsed().as_secs_f64() * 1e6;
        let t1 = Instant::now();
        let mut outcome = MigrationOutcome::default();
        for task in &plan.tasks {
            // Donor is pool 0; ship blocks + re-point ownership, the
            // same per-prefix protocol the live server drives over the
            // fabric.
            let (head, tail) = pools.split_at_mut(1);
            let receiver = &mut tail[task.to.0 as usize - 1];
            let o = migrate_prefix(
                &mut head[0],
                receiver,
                &task.tokens,
                TransferMode::ByRequestAgg,
                now_drain,
            )
            .expect("migration");
            tree.apply_delta(&DeltaEvent::Handoff {
                from: task.from,
                to: task.to,
                tokens: task.tokens[..o.moved_tokens].to_vec(),
                now: now_drain,
            });
            outcome.absorb(&o);
        }
        let exec_us = t1.elapsed().as_secs_f64() * 1e6;
        (outcome, plan.dropped_blocks, plan_us, exec_us)
    } else {
        (
            MigrationOutcome::default(),
            tree.cached_blocks(InstanceId(0)),
            0.0,
            0.0,
        )
    };
    tree.apply_delta(&DeltaEvent::Leave {
        instance: InstanceId(0),
    });

    // --- Measure: fleet-wide hit rate on the victim's hot prefixes,
    // verified against the receiving pool's actual index + data. ---
    let mut retention = 0.0;
    for p in &hot_prompts {
        let frac = best_match(&mut tree, p);
        if frac > 0.0 {
            // The tree's claim must be backed by a real pool: find the
            // owner and check its index (and one block of data).
            let holder = (1..n)
                .find(|&i| {
                    pools[i].match_prefix(p, now_drain).tokens == p.len()
                })
                .expect("tree claims a prefix no pool holds");
            let m = pools[holder].match_prefix(p, now_drain);
            let fpb = geom().floats_per_block();
            let mut buf = vec![0.0f32; fpb];
            pools[holder].read_block(m.groups[0][0], &mut buf).unwrap();
            assert!(buf[0] >= 0.0); // data landed (block readable)
        }
        retention += frac / hot_prompts.len() as f64;
    }
    SurvivalRun {
        retention,
        outcome,
        dropped_blocks: dropped,
        plan_us,
        exec_us,
    }
}

fn survival(n: usize) {
    let mut table = Table::new("fig16_prefix_survival", &[
        "instances",
        "variant",
        "hot_retention",
        "moved_token_blocks",
        "dropped_token_blocks",
        "wire_mb",
        "wire_calls",
        "plan_us",
        "exec_us",
    ]);
    println!(
        "\n-- prefix-hit survival across a drain ({n}-instance fleet, \
         real pools + block copies) --"
    );
    for migrate in [true, false] {
        let r = survival_run(n, migrate);
        let variant = if migrate { "migrate_drain" } else { "naive_drain" };
        table.row(vec![
            n.to_string(),
            variant.into(),
            format!("{:.3}", r.retention),
            r.outcome.moved_token_blocks.to_string(),
            r.dropped_blocks.to_string(),
            format!("{:.2}", r.outcome.wire_bytes as f64 / 1e6),
            r.outcome.wire_calls.to_string(),
            format!("{:.1}", r.plan_us),
            format!("{:.1}", r.exec_us),
        ]);
        println!(
            "  {variant:13}: retention {:.1}%  moved {} tb  dropped {} tb",
            r.retention * 100.0,
            r.outcome.moved_token_blocks,
            r.dropped_blocks
        );
        // Acceptance: migration retains ≥80% of the hot-prefix hit
        // rate; naive decommission drops to ~0%.
        if migrate {
            assert!(
                r.retention >= 0.8,
                "migrate-on-drain retention too low: {}",
                r.retention
            );
            assert!(r.outcome.moved_token_blocks > 0);
        } else {
            assert!(
                r.retention <= 0.05,
                "naive drain should lose the cache: {}",
                r.retention
            );
        }
    }
    table.finish();
}

fn sim_report_row(
    table: &mut Table,
    scenario: &str,
    rep: &SimReport,
    after: f64,
) {
    let post: Vec<_> = rep
        .metrics
        .records
        .iter()
        .filter(|r| r.scheduled > after)
        .collect();
    let post_ratio = if post.is_empty() {
        0.0
    } else {
        post.iter()
            .map(|r| r.cached_tokens as f64 / r.prompt_tokens.max(1) as f64)
            .sum::<f64>()
            / post.len() as f64
    };
    let m = &rep.metrics;
    table.row(vec![
        scenario.into(),
        m.records.len().to_string(),
        format!("{:.3}", post_ratio),
        format!("{:.4}", m.ttft().mean),
        format!("{:.4}", m.ttft().p99),
        format!("{:.4}", m.jct().mean),
        format!("{:.4}", m.jct().p99),
        rep.migrated_token_blocks.to_string(),
        rep.dropped_token_blocks.to_string(),
    ]);
}

fn elastic_sim(sessions: usize) {
    let change_at = 6.0;
    let mk = |fleet: Vec<FleetEvent>| SimConfig {
        prefill_instances: 4,
        decode_instances: 2,
        colocated_instances: 0,
        fleet,
        ..Default::default()
    };
    let spec = WorkloadSpec::generate(
        WorkloadKind::Loogle,
        sessions,
        16,
        2048,
        4096,
    );
    let plan = ArrivalPlan::poisson(&spec, 10.0, 16);
    let total = spec.total_requests();
    println!(
        "\n-- elastic sim: {sessions} LooGLE sessions ({total} requests), \
         fleet change at t={change_at}s --"
    );
    let mut table = Table::new("fig16_elastic_sim", &[
        "scenario",
        "n",
        "post_change_cached_ratio",
        "ttft_mean_s",
        "ttft_p99_s",
        "jct_mean_s",
        "jct_p99_s",
        "migrated_tb",
        "dropped_tb",
    ]);
    let scenarios: Vec<(&str, Vec<FleetEvent>)> = vec![
        ("steady", vec![]),
        (
            "migrate_drain",
            vec![FleetEvent {
                at: change_at,
                op: FleetOp::Drain {
                    inst: 0,
                    migrate: true,
                },
            }],
        ),
        (
            "naive_drain",
            vec![FleetEvent {
                at: change_at,
                op: FleetOp::Drain {
                    inst: 0,
                    migrate: false,
                },
            }],
        ),
        (
            "join",
            vec![FleetEvent {
                at: change_at,
                op: FleetOp::Join {
                    kind: InstanceKind::PrefillOnly,
                },
            }],
        ),
    ];
    for (name, fleet) in scenarios {
        let rep = Simulation::new(mk(fleet), spec.clone(), &plan).run();
        // Zero active-request loss under every fleet change (the sim
        // also asserts no route ever touches a non-Active instance).
        assert_eq!(
            rep.metrics.records.len(),
            total,
            "{name}: requests lost"
        );
        sim_report_row(&mut table, name, &rep, change_at);
    }
    table.finish();
    println!(
        "\nExpected shape: migrate_drain keeps the post-change cached \
         ratio near steady (and JCT close to it); naive_drain pays cold \
         re-prefills for every session the drained instance served; join \
         absorbs load with no disruption."
    );
}

fn main() {
    let mode = std::env::var("MEMSERVE_FIG16_MODE").unwrap_or_default();
    let n: usize = std::env::var("MEMSERVE_FIG16_N")
        .ok()
        .and_then(|s| s.parse().ok())
        .filter(|&n| n >= 2)
        .unwrap_or(4);
    let sessions: usize = std::env::var("MEMSERVE_FIG16_SESSIONS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(50);
    if mode != "sim" {
        survival(n);
    }
    if mode != "survival" {
        elastic_sim(sessions);
    }
}
