//! Fig 8 reproduction: end-to-end JCT / TTFT / TPOT for the four
//! settings (PD, PD-CC, 1P1D, 1P1D-CC) across the three workloads and a
//! request-rate sweep, on the discrete-event simulator with the
//! paper-scale (13B/H800-class) cost model.
//!
//! Rates are per instance (paper: "the request rate is calculated per
//! instance"; every setting runs 2 instances total).

use memserve::engine::DisaggMilestone;
use memserve::sim::{SimConfig, Simulation};
use memserve::util::bench::Table;
use memserve::workload::{ArrivalPlan, WorkloadKind, WorkloadSpec};

struct Setting {
    name: &'static str,
    prefill: usize,
    decode: usize,
    colocated: usize,
    caching: bool,
    milestone: DisaggMilestone,
}

fn settings() -> Vec<Setting> {
    vec![
        Setting {
            name: "PD",
            prefill: 0,
            decode: 0,
            colocated: 2,
            caching: false,
            milestone: DisaggMilestone::PdBasic,
        },
        Setting {
            name: "PD-CC",
            prefill: 0,
            decode: 0,
            colocated: 2,
            caching: true,
            milestone: DisaggMilestone::PdCaching3,
        },
        Setting {
            name: "1P1D",
            prefill: 1,
            decode: 1,
            colocated: 0,
            caching: false,
            milestone: DisaggMilestone::PdBasic,
        },
        Setting {
            name: "1P1D-CC",
            prefill: 1,
            decode: 1,
            colocated: 0,
            caching: true,
            milestone: DisaggMilestone::PdCaching3,
        },
        Setting {
            name: "2P1D-CC",
            prefill: 2,
            decode: 1,
            colocated: 0,
            caching: true,
            milestone: DisaggMilestone::PdCaching3,
        },
        Setting {
            name: "1P2D-CC",
            prefill: 1,
            decode: 2,
            colocated: 0,
            caching: true,
            milestone: DisaggMilestone::PdCaching3,
        },
    ]
}

fn main() {
    let seed = 11;
    let sessions = 60;
    let mut table = Table::new("fig8_end_to_end", &[
        "workload", "setting", "rate_per_inst", "n", "cached_ratio",
        "jct_mean_s", "jct_p99_s", "ttft_mean_s", "ttft_p99_s",
        "tpot_mean_s",
    ]);
    for kind in WorkloadKind::all() {
        let spec =
            WorkloadSpec::generate(kind, sessions, seed, 2048, 4096);
        for &rate_per_inst in &[0.5f64, 1.0, 2.0, 4.0] {
            for s in settings() {
                // Paper: "the request rate is calculated per instance".
                let n_inst = s.prefill + s.decode + s.colocated;
                let plan = ArrivalPlan::poisson(
                    &spec, rate_per_inst * n_inst as f64, seed);
                let cfg = SimConfig {
                    prefill_instances: s.prefill,
                    decode_instances: s.decode,
                    colocated_instances: s.colocated,
                    caching: s.caching,
                    milestone: s.milestone,
                    ..Default::default()
                };
                let rep =
                    Simulation::new(cfg, spec.clone(), &plan).run();
                let m = &rep.metrics;
                table.row(vec![
                    kind.name().into(),
                    s.name.into(),
                    format!("{rate_per_inst}"),
                    m.records.len().to_string(),
                    format!("{:.3}", m.mean_cached_ratio()),
                    format!("{:.3}", m.jct().mean),
                    format!("{:.3}", m.jct().p99),
                    format!("{:.3}", m.ttft().mean),
                    format!("{:.3}", m.ttft().p99),
                    format!("{:.4}", m.tpot().mean),
                ]);
            }
        }
    }
    table.finish();
    println!(
        "\nExpected shape (paper Fig 8): 1P1D improves JCT over PD \
         (interference removal); adding CC improves JCT further and cuts \
         TTFT strongly — most on LooGLE/ReAct (long shared prompts), \
         moderately on ShareGPT; gaps widen with rate."
    );
}
