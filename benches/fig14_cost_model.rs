//! Fig 14 reproduction: cost-model accuracy.
//!   (a) operator-level model vs measured prefill time on the REAL PJRT
//!       runtime (self-skips without artifacts; uses
//!       artifacts/cost_model.json when `memserve calibrate` has run,
//!       otherwise calibrates inline);
//!   (b) operator-level vs arch-level scalability across TP — fit both
//!       at TP=2, predict TP=1/TP=4 against the analytic ground truth.

use memserve::runtime::artifacts::artifacts_available;
use memserve::runtime::ModelRuntime;
use memserve::scheduler::cost_model::{
    model_from_json, ArchCostModel, OperatorCostModel,
};
use memserve::util::bench::Table;
use memserve::util::json::Json;

fn panel_a_real_runtime() {
    if !artifacts_available("artifacts") {
        println!("[fig14a skipped: run `make artifacts` first]");
        return;
    }
    let runtime = ModelRuntime::load("artifacts").expect("runtime");
    let meta = runtime.meta.clone();
    // Load the calibrated model if present; otherwise quick inline fit.
    let model = std::fs::read_to_string("artifacts/cost_model.json")
        .ok()
        .and_then(|t| Json::parse(&t).ok())
        .and_then(|j| model_from_json(&j));
    let toks = |n: usize| -> Vec<u32> {
        (0..n as u32).map(|i| (i * 31 + 7) % meta.vocab as u32).collect()
    };
    let measure = |x: usize, cached: usize| -> f64 {
        let prompt = toks(x);
        let cache = if cached > 0 {
            let out = runtime.prefill(&prompt[..cached], None, 0).unwrap();
            let cap = meta
                .pick_prefill_bucket(x - cached, cached)
                .map(|(_, c)| c)
                .unwrap();
            let s = meta.n_heads * meta.head_dim;
            let mut buf = vec![0f32; meta.layers * 2 * cap * s];
            for l in 0..meta.layers {
                for h in 0..2 {
                    for t in 0..cached {
                        let src = ((l * 2 + h) * out.bucket_n + t) * s;
                        let dst = ((l * 2 + h) * cap + t) * s;
                        buf[dst..dst + s]
                            .copy_from_slice(&out.new_kv[src..src + s]);
                    }
                }
            }
            Some(buf)
        } else {
            None
        };
        let mut ts = vec![];
        for _ in 0..5 {
            let t0 = std::time::Instant::now();
            let _ = runtime
                .prefill(&prompt[cached..], cache.as_deref(), cached)
                .unwrap();
            ts.push(t0.elapsed().as_secs_f64());
        }
        ts.sort_by(|a, b| a.partial_cmp(b).unwrap());
        ts[ts.len() / 2]
    };
    // Inline calibration if no file: fit on a training grid.
    let model = model.unwrap_or_else(|| {
        // Inline bucket-aware fit (same as `memserve calibrate`).
        let mut m = OperatorCostModel::default_tiny();
        let t64 = measure(64, 0);
        let t256 = measure(256, 0);
        m.gemm_per_token = (t256 - t64) / 192.0;
        m.constant = t64 - m.gemm_per_token * 64.0;
        m.attn_a = -1e-12;
        m.attn_b = 2e-12;
        m.attn_c = 0.0;
        m.attn_d = 0.0;
        m.wave_tokens = 16;
        m.buckets = meta.prefill_buckets.iter().map(|&(n, _)| n).collect();
        m.buckets.sort_unstable();
        m.buckets.dedup();
        m.tp = 1;
        m
    });
    let mut t = Table::new("fig14a_operator_accuracy", &[
        "prompt", "cached", "measured_ms", "predicted_ms", "rel_err_pct",
    ]);
    // Holdout grid (different from the calibration points).
    for &(x, cached) in &[
        (96usize, 0usize),
        (96, 32),
        (160, 0),
        (160, 64),
        (224, 0),
        (224, 128),
        (320, 160),
    ] {
        let measured = measure(x, cached);
        let y = cached as f64 / x as f64;
        let pred = model.exec(x, y);
        t.row(vec![
            x.to_string(),
            cached.to_string(),
            format!("{:.2}", measured * 1e3),
            format!("{:.2}", pred * 1e3),
            format!("{:.1}", 100.0 * (pred - measured).abs() / measured),
        ]);
    }
    t.finish();
}

fn panel_b_tp_scaling() {
    // Ground truth: the analytic operator model at each TP.
    let truth_tp2 = OperatorCostModel::paper_13b(); // fitted at TP=2
    let mut samples = vec![];
    for x in (256..=4096).step_by(256) {
        for yi in 0..=3 {
            let y = yi as f64 / 4.0;
            samples.push((x, y, truth_tp2.exec(x, y)));
        }
    }
    let arch = ArchCostModel::fit(&samples, 2);
    let mut t = Table::new("fig14b_tp_scaling", &[
        "tp", "prompt", "true_ms", "operator_pred_ms", "arch_pred_ms",
        "operator_err_pct", "arch_err_pct",
    ]);
    for &tp in &[1usize, 2, 4] {
        let truth = truth_tp2.with_tp(tp);
        for &x in &[1024usize, 2048, 4096] {
            let true_t = truth.exec(x, 0.0);
            let op_pred = truth_tp2.with_tp(tp).exec(x, 0.0);
            let arch_pred = arch.exec_rescaled(x, 0.0, tp);
            t.row(vec![
                tp.to_string(),
                x.to_string(),
                format!("{:.2}", true_t * 1e3),
                format!("{:.2}", op_pred * 1e3),
                format!("{:.2}", arch_pred * 1e3),
                format!("{:.1}",
                        100.0 * (op_pred - true_t).abs() / true_t),
                format!("{:.1}",
                        100.0 * (arch_pred - true_t).abs() / true_t),
            ]);
        }
    }
    t.finish();
    println!(
        "\nExpected shape (paper Fig 14): operator-level predictions \
         track measurements within a few percent and transfer across TP \
         by rescaling only the parallel terms; naively rescaled \
         arch-level predictions degrade (~20% at TP changes) because the \
         serial fraction gets wrongly divided (Amdahl)."
    );
}

fn main() {
    panel_a_real_runtime();
    panel_b_tp_scaling();
}
