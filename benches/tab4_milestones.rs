//! Table 4 ablation: the four §5.1 design milestones (PD-Basic →
//! PD-Caching-3) on a multi-turn chat workload — what each added
//! mechanism buys (cache ratio, TTFT, wire traffic).

use memserve::engine::DisaggMilestone;
use memserve::sim::{SimConfig, Simulation};
use memserve::util::bench::Table;
use memserve::workload::{ArrivalPlan, WorkloadKind, WorkloadSpec};

fn main() {
    // Multi-turn chat (document-QA-flavored, the paper's motivating
    // scenario for the milestone ladder).
    let spec =
        WorkloadSpec::generate(WorkloadKind::ShareGpt, 60, 21, 2048, 4096);
    let plan = ArrivalPlan::poisson(&spec, 6.0, 21);
    let mut table = Table::new("tab4_milestones", &[
        "design", "caching", "cached_ratio", "ttft_mean_s", "ttft_p99_s",
        "jct_mean_s", "wire_GB", "wire_calls",
    ]);
    for m in DisaggMilestone::all() {
        let caching = m != DisaggMilestone::PdBasic;
        let cfg = SimConfig {
            prefill_instances: 1,
            decode_instances: 1,
            caching,
            milestone: m,
            ..Default::default()
        };
        let rep = Simulation::new(cfg, spec.clone(), &plan).run();
        let mm = &rep.metrics;
        table.row(vec![
            m.name().into(),
            caching.to_string(),
            format!("{:.3}", mm.mean_cached_ratio()),
            format!("{:.4}", mm.ttft().mean),
            format!("{:.4}", mm.ttft().p99),
            format!("{:.4}", mm.jct().mean),
            format!("{:.3}", rep.wire_bytes as f64 / 1e9),
            rep.wire_calls.to_string(),
        ]);
    }
    table.finish();
    println!(
        "\nExpected shape (paper Table 4 / §5.1): caching-1 cuts TTFT via \
         P-side reuse but re-ships the full prompt KV every turn; \
         caching-2 cuts wire traffic (incremental transfer); caching-3 \
         grows the P cache with decode output so multi-turn cached ratio \
         rises further."
    );
}
