//! Fig 17 (repo-original): the replicated global scheduler.
//!
//! Part 1 (`fig17_replica`): route cost and delta-replication overhead
//! vs replica count. Reads (the one-walk fleet match + Eq. 1 decision)
//! are served round-robin across replicas — replicas of the same log
//! prefix agree exactly, so R replicas give ~R× aggregate route
//! throughput at unchanged per-route latency; writes pay one
//! `apply_sync` (append + apply + fan-out + acks) per ownership delta.
//!
//! Part 2 (`fig17_failover`): failover blackout measured in routed
//! requests. A scripted op stream (route + record) runs against the
//! group and an uninterrupted single-tree reference; mid-stream the
//! primary is crashed and a follower promoted. With followers caught up
//! (`synced`), promotion catches up from retained log suffixes and the
//! blackout is **zero** divergent route decisions — the acceptance bar.
//! The `lagged` variant stops pumping before the crash, so deltas held
//! only by the dead primary are honestly lost and the blackout is
//! nonzero until re-records repair the view.
//!
//! Part 3 (`fig17_shard`, ISSUE 5): the prefix-range sharded tree's
//! **write scaling**. S shards split the record stream by first-block
//! fingerprint range, so each shard's log sequences ~1/S of the writes
//! (asserted) while route decisions stay byte-identical to the
//! unsharded group (asserted: zero divergent) — including across a
//! scripted mid-stream failover of one shard's primary.
//!
//! Part 4 (`fig17_threads`, ISSUE 7): **multi-threaded** per-shard
//! apply — T worker threads each own a static subset of the S shard
//! groups (shard s → thread s % T, preserving per-shard event order)
//! and drain their shards' pre-partitioned record streams
//! concurrently, measuring aggregate applies/sec and per-delta cost.
//! Final per-shard state (log heads + primary route-match probes) is
//! asserted equal to the sequential [`ShardedReplicaGroup`] applying
//! the identical stream — T=1 is the sequential code path itself, so
//! single-thread output is bit-identical by construction *and* by the
//! assert.
//!
//! Env knobs (used by the CI smoke job):
//! * `MEMSERVE_FIG17_MODE` — `sweep` (part 1), `failover` (part 2),
//!   `shards` (part 3), `threads` (part 4 only — opt-in so the default
//!   output stays byte-stable), anything else/unset runs parts 1–3;
//! * `MEMSERVE_FIG17_R` — comma-separated replica counts (default
//!   `1,2,4,8`; failover uses each count ≥ 2);
//! * `MEMSERVE_FIG17_S` — comma-separated shard counts for part 3
//!   (default `1,2,4,8`; part 4 uses the largest);
//! * `MEMSERVE_FIG17_T` — comma-separated thread counts for part 4
//!   (default `1,2,4,8`).

use std::sync::Mutex;
use std::time::Instant;

use memserve::elastic::delta::DeltaEvent;
use memserve::mempool::InstanceId;
use memserve::replica::{ReplicaGroup, ShardedReplicaGroup};
use memserve::scheduler::cost_model::OperatorCostModel;
use memserve::scheduler::policy::{decide, Candidate, Decision, PolicyKind};
use memserve::scheduler::prompt_tree::InstanceKind;
use memserve::scheduler::shard::ShardMap;
use memserve::util::bench::{black_box, time_adaptive, Table};

const BT: usize = 16;
const N_INSTANCES: u32 = 16;
/// Per-peer in-flight window of the bench transports (the GS_WINDOW
/// analogue — one bound of the lagged-failover loss).
const WINDOW: usize = 256;

fn prompt(n: usize, seed: u32) -> Vec<u32> {
    (0..n as u32)
        .map(|i| (i.wrapping_mul(2654435761).wrapping_add(seed)) % 50_000)
        .collect()
}

fn seed_group(r: usize) -> ReplicaGroup {
    let mut g = ReplicaGroup::new(r, BT, 0.0, WINDOW);
    for i in 0..N_INSTANCES {
        g.apply_sync(DeltaEvent::Join {
            instance: InstanceId(i),
            kind: InstanceKind::PrefillOnly,
        });
    }
    // A hot fleet-wide 4K prompt plus per-instance bulk (fig15's
    // regime), all through the replicated log.
    let hot = prompt(4096, 1);
    for i in 0..N_INSTANCES {
        g.apply_sync(DeltaEvent::Record {
            instance: InstanceId(i),
            tokens: hot.clone(),
            now: 1.0,
        });
        for k in 0..4u32 {
            g.apply_sync(DeltaEvent::Record {
                instance: InstanceId(i),
                tokens: prompt(4096, 1000 + i * 4 + k),
                now: 1.0,
            });
        }
    }
    g
}

fn route_on(
    g: &mut ReplicaGroup,
    replica: usize,
    tokens: &[u32],
    buf: &mut Vec<(InstanceId, usize)>,
    cost: &OperatorCostModel,
    sid: u64,
) -> Decision {
    g.route_match(replica, tokens, buf);
    let cands: Vec<Candidate> = buf
        .iter()
        .map(|&(id, matched)| Candidate {
            instance: id,
            queued_tokens: 0,
            queued_cached_ratio: 0.0,
            matched_tokens: matched,
            pressure: 0.0,
        })
        .collect();
    decide(PolicyKind::PromptTree, &cands, tokens.len(), sid, |x, y| {
        cost.exec(x, y)
    })
}

fn route_sweep(rs: &[usize]) {
    let mut table = Table::new("fig17_replica", &[
        "replicas",
        "instances",
        "route_us_mean",
        "route_us_p99",
        "delta_us_mean",
        "est_routes_per_s",
    ]);
    println!(
        "\n-- replicated GS: per-route cost (round-robin reads over R \
         replicas) and per-delta replication cost --"
    );
    let cost = OperatorCostModel::paper_13b();
    let hot = prompt(4096, 1);
    for &r in rs {
        let mut g = seed_group(r);
        let live = g.live_indices();
        let mut buf = vec![];
        let mut rr = 0usize;
        let mut route_t = time_adaptive(60.0, 100, || {
            let replica = live[rr % live.len()];
            rr += 1;
            black_box(route_on(&mut g, replica, &hot, &mut buf, &cost, 7));
        });
        let mut k = 0u32;
        let mut delta_t = time_adaptive(60.0, 100, || {
            k += 1;
            g.apply_sync(DeltaEvent::Record {
                instance: InstanceId(k % N_INSTANCES),
                tokens: prompt(256, 50_000 + k),
                now: 2.0,
            });
        });
        let (rm, dm) = (route_t.mean(), delta_t.mean());
        let est = r as f64 * 1e6 / rm.max(1e-9);
        table.row(vec![
            r.to_string(),
            N_INSTANCES.to_string(),
            format!("{rm:.2}"),
            format!("{:.2}", route_t.p99()),
            format!("{dm:.2}"),
            format!("{est:.0}"),
        ]);
        println!(
            "  R={r}: route {rm:8.2}us  delta {dm:8.2}us  (~{est:.0} \
             aggregate routes/s)"
        );
    }
    table.finish();
    println!(
        "\nExpected shape: route_us flat in R (replicas serve reads \
         independently — aggregate throughput scales ~R×); delta_us \
         grows mildly with R (fan-out + acks per write)."
    );
}

fn failover(rs: &[usize]) {
    let mut table = Table::new("fig17_failover", &[
        "replicas",
        "variant",
        "ops",
        "failover_at",
        "blackout_requests",
        "promote_us",
    ]);
    println!(
        "\n-- failover blackout: divergent route decisions after a \
         primary crash (synced = catch-up complete; lagged = deltas \
         held only by the dead primary are lost) --"
    );
    let cost = OperatorCostModel::paper_13b();
    let n_ops = 1200usize;
    let crash_at = n_ops / 2;
    // Sessions in the op stream, and how many ops the lagged variant
    // withholds from the followers before the crash. WITHHOLD <
    // SESSIONS keeps the derived blackout bound non-vacuous: each lost
    // entry is one distinct session's Record.
    const SESSIONS: usize = 64;
    const WITHHOLD: usize = 16;
    for &r in rs {
        if r < 2 {
            continue; // failover needs a follower
        }
        for variant in ["synced", "lagged"] {
            let mut g = seed_group(r);
            // The uninterrupted reference: same deltas, one tree.
            let mut reference = seed_group(1);
            let mut buf = vec![];
            let mut rbuf = vec![];
            let mut blackout = 0usize;
            let mut promote_us = 0.0;
            let mut crashed = false;
            let mut lost_entries = 0usize;
            for op in 0..n_ops {
                let sid = (op % SESSIONS) as u64;
                let p = prompt(1024, 7 + sid as u32);
                if op == crash_at {
                    // Entries only the dead primary holds: the gap
                    // between the log head and the best follower (the
                    // promotion target).
                    let best = g
                        .live_indices()
                        .into_iter()
                        .filter(|&i| i != g.primary_index())
                        .map(|i| g.applied_seq(i))
                        .max()
                        .expect("followers exist");
                    lost_entries = (g.log_head() - best) as usize;
                    let t0 = Instant::now();
                    g.fail_primary().expect("a follower survives");
                    promote_us = t0.elapsed().as_secs_f64() * 1e6;
                    crashed = true;
                }
                let pi = g.primary_index();
                let d = route_on(&mut g, pi, &p, &mut buf, &cost, sid);
                let dref = route_on(
                    &mut reference,
                    0,
                    &p,
                    &mut rbuf,
                    &cost,
                    sid,
                );
                if crashed && d != dref {
                    blackout += 1;
                }
                // Response path: the chosen instance caches the prompt.
                let ev = DeltaEvent::Record {
                    instance: d.instance,
                    tokens: p,
                    now: 3.0 + op as f64 * 1e-3,
                };
                let evr = DeltaEvent::Record {
                    instance: dref.instance,
                    tokens: prompt(1024, 7 + sid as u32),
                    now: 3.0 + op as f64 * 1e-3,
                };
                reference.apply_sync(evr);
                if variant == "lagged"
                    && !crashed
                    && op + WITHHOLD >= crash_at
                {
                    // The last WITHHOLD appends before the crash never
                    // leave the primary: appended, applied locally, not
                    // pumped.
                    g.apply(ev);
                } else {
                    g.apply_sync(ev);
                }
            }
            if variant == "synced" {
                assert_eq!(
                    lost_entries, 0,
                    "synced crash must not strand log entries"
                );
                assert_eq!(
                    blackout, 0,
                    "synced failover must lose zero route decisions"
                );
            } else {
                // ISSUE 5 satellite: the lagged blackout is BOUNDED
                // from the ack window, not merely measured. (1) The
                // promotee can be missing at most the unpumped window:
                // min(WITHHOLD, per-peer in-flight WINDOW) entries per
                // shard — with pumping after every append (the live
                // gs_apply flush), WINDOW is the hard cap. (2) Each
                // lost entry is one session's Record over that
                // session's private prompt, so at most `lost_entries`
                // sessions can route differently from the reference —
                // for at most their post-crash route count each.
                assert!(
                    lost_entries <= WITHHOLD.min(WINDOW),
                    "lost {lost_entries} > window bound"
                );
                let rounds_per_session =
                    (n_ops - crash_at).div_ceil(SESSIONS);
                let bound = lost_entries * rounds_per_session;
                assert!(
                    blackout <= bound,
                    "lagged blackout {blackout} exceeds the derived \
                     bound {bound} ({lost_entries} lost entries × \
                     {rounds_per_session} post-crash rounds)"
                );
            }
            table.row(vec![
                r.to_string(),
                variant.into(),
                n_ops.to_string(),
                crash_at.to_string(),
                blackout.to_string(),
                format!("{promote_us:.1}"),
            ]);
            println!(
                "  R={r} {variant:6}: blackout {blackout:4} of \
                 {} post-crash routes, promotion {promote_us:.1}us",
                n_ops - crash_at
            );
        }
    }
    table.finish();
    println!(
        "\nExpected shape: synced blackout = 0 (promotion catch-up \
         restores the exact tree); lagged blackout > 0 but bounded by \
         the unpumped window, decaying as re-records repair the view."
    );
}

/// Route through the sharded group's per-shard primaries (valid across
/// per-shard failovers).
fn route_sharded(
    g: &mut ShardedReplicaGroup,
    tokens: &[u32],
    buf: &mut Vec<(InstanceId, usize)>,
    cost: &OperatorCostModel,
    sid: u64,
) -> Decision {
    g.route_match_primary(tokens, buf);
    let cands: Vec<Candidate> = buf
        .iter()
        .map(|&(id, matched)| Candidate {
            instance: id,
            queued_tokens: 0,
            queued_cached_ratio: 0.0,
            matched_tokens: matched,
            pressure: 0.0,
        })
        .collect();
    decide(PolicyKind::PromptTree, &cands, tokens.len(), sid, |x, y| {
        cost.exec(x, y)
    })
}

fn shard_sweep(ss: &[usize]) {
    let mut table = Table::new("fig17_shard", &[
        "shards",
        "replicas_per_shard",
        "writes",
        "per_shard_mean",
        "per_shard_max",
        "divergent",
        "apply_us_mean",
    ]);
    println!(
        "\n-- sharded GS write scaling: records split by first-block \
         fingerprint range — each shard's log sequences ~1/S of the \
         writes; decisions must equal the unsharded group's exactly, \
         across a mid-stream failover of the last shard's primary --"
    );
    let cost = OperatorCostModel::paper_13b();
    const WRITES: u32 = 256;
    for &s in ss {
        let mut g = ShardedReplicaGroup::new(s, 2, BT, 0.0, WINDOW);
        let mut reference = ShardedReplicaGroup::new(1, 1, BT, 0.0,
                                                     WINDOW);
        for i in 0..N_INSTANCES {
            let join = DeltaEvent::Join {
                instance: InstanceId(i),
                kind: InstanceKind::PrefillOnly,
            };
            g.apply_sync(join.clone());
            reference.apply_sync(join);
        }
        let base: Vec<u64> = (0..s).map(|i| g.log_head(i)).collect();
        let mut buf = vec![];
        let mut rbuf = vec![];
        let mut divergent = 0usize;
        let mut apply_s = 0.0f64;
        for k in 0..WRITES {
            let p = prompt(1024, 100 + k);
            let sid = (k % 64) as u64;
            let d = route_sharded(&mut g, &p, &mut buf, &cost, sid);
            let dref =
                route_sharded(&mut reference, &p, &mut rbuf, &cost, sid);
            if d != dref {
                divergent += 1;
            }
            // Keep both streams identical regardless of decisions: the
            // instance is derived from k, not from d (so one divergence
            // cannot cascade and hide itself).
            let ev = DeltaEvent::Record {
                instance: InstanceId(k % N_INSTANCES),
                tokens: p,
                now: 1.0 + k as f64 * 1e-3,
            };
            let t0 = Instant::now();
            g.apply_sync(ev.clone());
            apply_s += t0.elapsed().as_secs_f64();
            reference.apply_sync(ev);
            if s >= 2 && k == WRITES / 2 {
                // Mid-stream per-shard failover: the last shard's
                // primary crashes and promotes; the other shards (and
                // the reference) never notice.
                g.fail_primary(s - 1).expect("a follower survives");
            }
        }
        assert_eq!(
            divergent, 0,
            "sharded routing diverged from the unsharded group (S={s})"
        );
        // Write scaling: every record sequenced exactly once, split
        // across the shards by fingerprint range.
        let per_shard: Vec<u64> = (0..s)
            .map(|i| g.log_head(i) - base[i])
            .collect();
        let total: u64 = per_shard.iter().sum();
        assert_eq!(total, WRITES as u64, "records must shard exactly once");
        let max = *per_shard.iter().max().unwrap();
        let mean = total as f64 / s as f64;
        assert!(
            (max as f64) <= (3.0 * mean).max(8.0),
            "shard skew: max {max} vs mean {mean:.1} (S={s})"
        );
        let apply_us = apply_s * 1e6 / WRITES as f64;
        table.row(vec![
            s.to_string(),
            "2".into(),
            WRITES.to_string(),
            format!("{mean:.1}"),
            max.to_string(),
            divergent.to_string(),
            format!("{apply_us:.2}"),
        ]);
        println!(
            "  S={s}: per-shard applied mean {mean:6.1} max {max:4} \
             (of {WRITES} writes)  divergent {divergent}  apply \
             {apply_us:.2}us"
        );
    }
    table.finish();
    println!(
        "\nExpected shape: per_shard_mean = writes/S (each shard's log \
         and replica apply stream carries ~1/S of the write load — the \
         S-way parallel headroom); divergent = 0 always."
    );
}

/// Part 4: T apply threads over S per-shard replica groups (module
/// docs). Each thread owns shards `{s : s % T == t}` outright for the
/// run, so per-shard event order is the reference's order and no two
/// threads ever contend on one group — the `Mutex` per group exists
/// only to satisfy the compiler's aliasing rules across the scope.
fn thread_apply_sweep(ts: &[usize], shards: usize) {
    const WRITES: u32 = 2048;
    let mut table = Table::new("fig17_threads", &[
        "threads", "shards", "writes", "applies_per_sec", "apply_us",
        "divergent_probes",
    ]);
    println!(
        "\n-- threaded per-shard apply: T threads x {shards} shard \
         groups, {WRITES} records (static shard->thread assignment; \
         final state vs sequential sharded group) --"
    );
    let cost = OperatorCostModel::paper_13b();
    // Pre-partition the record stream by shard — stable partition, so
    // each shard's sub-stream order equals the sequential reference's.
    let map = ShardMap::new(shards, BT);
    let mut per_shard_events: Vec<Vec<DeltaEvent>> =
        vec![vec![]; shards];
    for k in 0..WRITES {
        let t = prompt(1024, 100 + k);
        let s = map.shard_of_tokens(&t).unwrap_or(0);
        per_shard_events[s].push(DeltaEvent::Record {
            instance: InstanceId(k % N_INSTANCES),
            tokens: t,
            now: 1.0 + k as f64 * 1e-3,
        });
    }
    // The sequential reference: the ISSUE-5 sharded group applying the
    // identical stream in original order.
    let mut reference =
        ShardedReplicaGroup::new(shards, 2, BT, 0.0, WINDOW);
    for i in 0..N_INSTANCES {
        reference.apply_sync(DeltaEvent::Join {
            instance: InstanceId(i),
            kind: InstanceKind::PrefillOnly,
        });
    }
    for evs in &per_shard_events {
        for ev in evs {
            reference.apply_sync(ev.clone());
        }
    }
    let probes: Vec<Vec<u32>> =
        (0..32u32).map(|k| prompt(1024, 100 + k * 7)).collect();
    for &t_count in ts {
        // Fresh groups per T: membership fans to every shard exactly
        // as ShardedReplicaGroup does.
        let groups: Vec<Mutex<ReplicaGroup>> = (0..shards)
            .map(|_| {
                let mut g = ReplicaGroup::new(2, BT, 0.0, WINDOW);
                for i in 0..N_INSTANCES {
                    g.apply_sync(DeltaEvent::Join {
                        instance: InstanceId(i),
                        kind: InstanceKind::PrefillOnly,
                    });
                }
                Mutex::new(g)
            })
            .collect();
        let start = Instant::now();
        std::thread::scope(|sc| {
            for t in 0..t_count {
                let groups = &groups;
                let per_shard_events = &per_shard_events;
                sc.spawn(move || {
                    for s in (t..shards).step_by(t_count.max(1)) {
                        let mut g = groups[s].lock().unwrap();
                        for ev in &per_shard_events[s] {
                            g.apply_sync(ev.clone());
                        }
                    }
                });
            }
        });
        let elapsed = start.elapsed().as_secs_f64();
        let aps = WRITES as f64 / elapsed.max(1e-12);
        let apply_us = elapsed * 1e6 / WRITES as f64;
        // Differential: log heads and primary route-matches must equal
        // the sequential reference's, shard for shard.
        let mut divergent = 0usize;
        for s in 0..shards {
            let g = groups[s].lock().unwrap();
            assert_eq!(
                g.log_head(),
                reference.log_head(s),
                "T={t_count}: shard {s} log head drifted"
            );
        }
        let mut buf = vec![];
        let mut rbuf = vec![];
        for p in &probes {
            let s = map.shard_of_tokens(p).unwrap_or(0);
            let mut g = groups[s].lock().unwrap();
            let pi = g.primary_index();
            g.route_match(pi, p, &mut buf);
            reference.route_match_primary(p, &mut rbuf);
            if buf != rbuf {
                divergent += 1;
            }
            // The full Eq.-1 decision, too — the externally visible
            // contract.
            let d = decide(
                PolicyKind::PromptTree,
                &buf.iter()
                    .map(|&(id, matched)| Candidate {
                        instance: id,
                        queued_tokens: 0,
                        queued_cached_ratio: 0.0,
                        matched_tokens: matched,
                        pressure: 0.0,
                    })
                    .collect::<Vec<_>>(),
                p.len(),
                7,
                |x, y| cost.exec(x, y),
            );
            black_box(d);
        }
        assert_eq!(
            divergent, 0,
            "T={t_count}: threaded per-shard state diverged from the \
             sequential sharded group"
        );
        table.row(vec![
            t_count.to_string(),
            shards.to_string(),
            WRITES.to_string(),
            format!("{aps:.0}"),
            format!("{apply_us:.2}"),
            divergent.to_string(),
        ]);
        println!(
            "  T={t_count}: {aps:9.0} applies/sec  ({apply_us:.2}us \
             per delta)  divergent {divergent}"
        );
    }
    table.finish();
    println!(
        "\nExpected shape: applies/sec grows with T until min(T, S) \
         saturates the cores — per-shard logs sequence independently, \
         so the apply path has no cross-thread contention at all."
    );
}

fn main() {
    let mode = std::env::var("MEMSERVE_FIG17_MODE").unwrap_or_default();
    let list = |var: &str, default: &[usize]| -> Vec<usize> {
        std::env::var(var)
            .ok()
            .map(|s| {
                s.split(',')
                    .filter_map(|x| x.trim().parse().ok())
                    .collect::<Vec<usize>>()
            })
            .filter(|v| !v.is_empty())
            .unwrap_or_else(|| default.to_vec())
    };
    let rs = list("MEMSERVE_FIG17_R", &[1, 2, 4, 8]);
    let ss = list("MEMSERVE_FIG17_S", &[1, 2, 4, 8]);
    if mode == "threads" {
        let ts = list("MEMSERVE_FIG17_T", &[1, 2, 4, 8]);
        let shards = ss.iter().copied().max().unwrap_or(4).max(1);
        thread_apply_sweep(&ts, shards);
        return;
    }
    let all = !matches!(mode.as_str(), "sweep" | "failover" | "shards");
    if all || mode == "sweep" {
        route_sweep(&rs);
    }
    if all || mode == "failover" {
        failover(&rs);
    }
    if all || mode == "shards" {
        shard_sweep(&ss);
    }
}
