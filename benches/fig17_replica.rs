//! Fig 17 (repo-original): the replicated global scheduler.
//!
//! Part 1 (`fig17_replica`): route cost and delta-replication overhead
//! vs replica count. Reads (the one-walk fleet match + Eq. 1 decision)
//! are served round-robin across replicas — replicas of the same log
//! prefix agree exactly, so R replicas give ~R× aggregate route
//! throughput at unchanged per-route latency; writes pay one
//! `apply_sync` (append + apply + fan-out + acks) per ownership delta.
//!
//! Part 2 (`fig17_failover`): failover blackout measured in routed
//! requests. A scripted op stream (route + record) runs against the
//! group and an uninterrupted single-tree reference; mid-stream the
//! primary is crashed and a follower promoted. With followers caught up
//! (`synced`), promotion catches up from retained log suffixes and the
//! blackout is **zero** divergent route decisions — the acceptance bar.
//! The `lagged` variant stops pumping before the crash, so deltas held
//! only by the dead primary are honestly lost and the blackout is
//! nonzero until re-records repair the view.
//!
//! Env knobs (used by the CI smoke job):
//! * `MEMSERVE_FIG17_MODE` — `sweep` (part 1), `failover` (part 2),
//!   anything else/unset runs both;
//! * `MEMSERVE_FIG17_R` — comma-separated replica counts (default
//!   `1,2,4,8`; failover uses each count ≥ 2).

use std::time::Instant;

use memserve::elastic::delta::DeltaEvent;
use memserve::mempool::InstanceId;
use memserve::replica::ReplicaGroup;
use memserve::scheduler::cost_model::OperatorCostModel;
use memserve::scheduler::policy::{decide, Candidate, Decision, PolicyKind};
use memserve::scheduler::prompt_tree::InstanceKind;
use memserve::util::bench::{black_box, time_adaptive, Table};

const BT: usize = 16;
const N_INSTANCES: u32 = 16;

fn prompt(n: usize, seed: u32) -> Vec<u32> {
    (0..n as u32)
        .map(|i| (i.wrapping_mul(2654435761).wrapping_add(seed)) % 50_000)
        .collect()
}

fn seed_group(r: usize) -> ReplicaGroup {
    let mut g = ReplicaGroup::new(r, BT, 0.0, 256);
    for i in 0..N_INSTANCES {
        g.apply_sync(DeltaEvent::Join {
            instance: InstanceId(i),
            kind: InstanceKind::PrefillOnly,
        });
    }
    // A hot fleet-wide 4K prompt plus per-instance bulk (fig15's
    // regime), all through the replicated log.
    let hot = prompt(4096, 1);
    for i in 0..N_INSTANCES {
        g.apply_sync(DeltaEvent::Record {
            instance: InstanceId(i),
            tokens: hot.clone(),
            now: 1.0,
        });
        for k in 0..4u32 {
            g.apply_sync(DeltaEvent::Record {
                instance: InstanceId(i),
                tokens: prompt(4096, 1000 + i * 4 + k),
                now: 1.0,
            });
        }
    }
    g
}

fn route_on(
    g: &mut ReplicaGroup,
    replica: usize,
    tokens: &[u32],
    buf: &mut Vec<(InstanceId, usize)>,
    cost: &OperatorCostModel,
    sid: u64,
) -> Decision {
    g.route_match(replica, tokens, buf);
    let cands: Vec<Candidate> = buf
        .iter()
        .map(|&(id, matched)| Candidate {
            instance: id,
            queued_tokens: 0,
            queued_cached_ratio: 0.0,
            matched_tokens: matched,
            pressure: 0.0,
        })
        .collect();
    decide(PolicyKind::PromptTree, &cands, tokens.len(), sid, |x, y| {
        cost.exec(x, y)
    })
}

fn route_sweep(rs: &[usize]) {
    let mut table = Table::new("fig17_replica", &[
        "replicas",
        "instances",
        "route_us_mean",
        "route_us_p99",
        "delta_us_mean",
        "est_routes_per_s",
    ]);
    println!(
        "\n-- replicated GS: per-route cost (round-robin reads over R \
         replicas) and per-delta replication cost --"
    );
    let cost = OperatorCostModel::paper_13b();
    let hot = prompt(4096, 1);
    for &r in rs {
        let mut g = seed_group(r);
        let live = g.live_indices();
        let mut buf = vec![];
        let mut rr = 0usize;
        let mut route_t = time_adaptive(60.0, 100, || {
            let replica = live[rr % live.len()];
            rr += 1;
            black_box(route_on(&mut g, replica, &hot, &mut buf, &cost, 7));
        });
        let mut k = 0u32;
        let mut delta_t = time_adaptive(60.0, 100, || {
            k += 1;
            g.apply_sync(DeltaEvent::Record {
                instance: InstanceId(k % N_INSTANCES),
                tokens: prompt(256, 50_000 + k),
                now: 2.0,
            });
        });
        let (rm, dm) = (route_t.mean(), delta_t.mean());
        let est = r as f64 * 1e6 / rm.max(1e-9);
        table.row(vec![
            r.to_string(),
            N_INSTANCES.to_string(),
            format!("{rm:.2}"),
            format!("{:.2}", route_t.p99()),
            format!("{dm:.2}"),
            format!("{est:.0}"),
        ]);
        println!(
            "  R={r}: route {rm:8.2}us  delta {dm:8.2}us  (~{est:.0} \
             aggregate routes/s)"
        );
    }
    table.finish();
    println!(
        "\nExpected shape: route_us flat in R (replicas serve reads \
         independently — aggregate throughput scales ~R×); delta_us \
         grows mildly with R (fan-out + acks per write)."
    );
}

fn failover(rs: &[usize]) {
    let mut table = Table::new("fig17_failover", &[
        "replicas",
        "variant",
        "ops",
        "failover_at",
        "blackout_requests",
        "promote_us",
    ]);
    println!(
        "\n-- failover blackout: divergent route decisions after a \
         primary crash (synced = catch-up complete; lagged = deltas \
         held only by the dead primary are lost) --"
    );
    let cost = OperatorCostModel::paper_13b();
    let n_ops = 1200usize;
    let crash_at = n_ops / 2;
    for &r in rs {
        if r < 2 {
            continue; // failover needs a follower
        }
        for variant in ["synced", "lagged"] {
            let mut g = seed_group(r);
            // The uninterrupted reference: same deltas, one tree.
            let mut reference = seed_group(1);
            let mut buf = vec![];
            let mut rbuf = vec![];
            let mut blackout = 0usize;
            let mut promote_us = 0.0;
            let mut crashed = false;
            for op in 0..n_ops {
                let sid = (op % 64) as u64;
                let p = prompt(1024, 7 + sid as u32);
                if op == crash_at {
                    let t0 = Instant::now();
                    g.fail_primary().expect("a follower survives");
                    promote_us = t0.elapsed().as_secs_f64() * 1e6;
                    crashed = true;
                }
                let pi = g.primary_index();
                let d = route_on(&mut g, pi, &p, &mut buf, &cost, sid);
                let dref = route_on(
                    &mut reference,
                    0,
                    &p,
                    &mut rbuf,
                    &cost,
                    sid,
                );
                if crashed && d != dref {
                    blackout += 1;
                }
                // Response path: the chosen instance caches the prompt.
                let ev = DeltaEvent::Record {
                    instance: d.instance,
                    tokens: p,
                    now: 3.0 + op as f64 * 1e-3,
                };
                let evr = DeltaEvent::Record {
                    instance: dref.instance,
                    tokens: prompt(1024, 7 + sid as u32),
                    now: 3.0 + op as f64 * 1e-3,
                };
                reference.apply_sync(evr);
                if variant == "lagged" && !crashed && op + 64 >= crash_at {
                    // The last window before the crash never leaves the
                    // primary: appended, applied locally, not pumped.
                    g.apply(ev);
                } else {
                    g.apply_sync(ev);
                }
            }
            if variant == "synced" {
                assert_eq!(
                    blackout, 0,
                    "synced failover must lose zero route decisions"
                );
            }
            table.row(vec![
                r.to_string(),
                variant.into(),
                n_ops.to_string(),
                crash_at.to_string(),
                blackout.to_string(),
                format!("{promote_us:.1}"),
            ]);
            println!(
                "  R={r} {variant:6}: blackout {blackout:4} of \
                 {} post-crash routes, promotion {promote_us:.1}us",
                n_ops - crash_at
            );
        }
    }
    table.finish();
    println!(
        "\nExpected shape: synced blackout = 0 (promotion catch-up \
         restores the exact tree); lagged blackout > 0 but bounded by \
         the unpumped window, decaying as re-records repair the view."
    );
}

fn main() {
    let mode = std::env::var("MEMSERVE_FIG17_MODE").unwrap_or_default();
    let rs: Vec<usize> = std::env::var("MEMSERVE_FIG17_R")
        .ok()
        .map(|s| {
            s.split(',')
                .filter_map(|x| x.trim().parse().ok())
                .collect::<Vec<usize>>()
        })
        .filter(|v| !v.is_empty())
        .unwrap_or_else(|| vec![1, 2, 4, 8]);
    if mode != "failover" {
        route_sweep(&rs);
    }
    if mode != "sweep" {
        failover(&rs);
    }
}
