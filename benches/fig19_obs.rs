//! Fig 19 (repo-original): cluster observability (ISSUE 8).
//!
//! Part 1 (`fig19_overhead`): the routing hot path with the metric
//! registry + trace sink attached vs bare, on the fig15 hot-fleet
//! shape (N instances, a 4K-token prompt cached fleet-wide). The
//! instrumented loop also pays the leader's per-request tracing work
//! (complete ROUTE, begin/end QUEUE) so the number is honest about the
//! whole route-path tax, not just the counter bumps.
//! `MEMSERVE_FIG19_GATE=1` turns the ≤5% median-throughput-regression
//! claim into a hard assert (`MEMSERVE_GATE_ATTEMPTS` re-measure
//! attempts, default 3, contended CI runners being what they are).
//!
//! Part 2 (`fig19_faults`): the fig18 blackout sim — lossy GS delta
//! replication plus a scripted mid-trace shard failover — run with
//! `observe: true`. Asserts every completed request closed a complete
//! span chain (route→queue→prefill→[kv_transfer→]decode→retire), zero
//! orphaned phase ends, and a non-empty flight recorder containing the
//! injected SUSPICION and the PROMOTION that answers it. The Chrome
//! trace JSON and the flight-recorder dump land in the
//! `MEMSERVE_BENCH_JSON` sink next to the tables.
//!
//! Env knobs (used by the CI smoke job):
//! * `MEMSERVE_FIG19_MODE` — `overhead`, `faults`, anything else/unset
//!   runs both;
//! * `MEMSERVE_FIG19_N` — instance count for the overhead part
//!   (default `16`);
//! * `MEMSERVE_FIG19_GATE` — `1` asserts the instrumented median
//!   routes/sec is within 5% of bare.

use memserve::engine::DisaggMilestone;
use memserve::mempool::InstanceId;
use memserve::obs::trace::phase;
use memserve::obs::{trace, Registry, TraceSink};
use memserve::scheduler::cost_model::OperatorCostModel;
use memserve::scheduler::prompt_tree::InstanceKind;
use memserve::scheduler::router::GlobalScheduler;
use memserve::scheduler::PolicyKind;
use memserve::sim::{FleetEvent, FleetOp, SimConfig, Simulation};
use memserve::util::bench::{
    bench_json_dir, black_box, gate_attempts, time_adaptive, Table,
};
use memserve::workload::{ArrivalPlan, WorkloadKind, WorkloadSpec};

fn prompt(n: usize, seed: u32) -> Vec<u32> {
    (0..n as u32)
        .map(|i| (i.wrapping_mul(2654435761).wrapping_add(seed)) % 50_000)
        .collect()
}

// ---------------------------------------------------------------------
// Part 1: instrumented vs bare route path.
// ---------------------------------------------------------------------

/// The fig15 hot-fleet scheduler: N prefill instances, the 4K prompt
/// cached on every one, 4 unique prompts each for tree bulk.
fn hot_scheduler(n: usize, hot: &[u32]) -> GlobalScheduler {
    const BT: usize = 16;
    let mut gs = GlobalScheduler::new(
        PolicyKind::PromptTree,
        OperatorCostModel::paper_13b(),
        BT,
        0.0,
    );
    for i in 0..n {
        gs.add_instance(InstanceId(i as u32), InstanceKind::PrefillOnly);
    }
    for i in 0..n {
        let id = InstanceId(i as u32);
        gs.trees.record(id, hot, 1.0);
        for k in 0..4u32 {
            gs.trees.record(id, &prompt(4096, 1000 + (i as u32) * 4 + k),
                            1.0);
        }
    }
    gs
}

/// One measurement of both variants; returns (bare, instrumented)
/// median routes/sec.
fn overhead_run(n: usize) -> (f64, f64) {
    let hot = prompt(4096, 1);

    let mut bare = hot_scheduler(n, &hot);
    let mut bare_t = time_adaptive(150.0, 200, || {
        black_box(bare.route(&hot, 7, 2.0).unwrap());
    });

    let mut inst = hot_scheduler(n, &hot);
    let reg = Registry::new(true);
    let sink = TraceSink::new(true);
    inst.attach_obs(&reg, None);
    // Spans cycle through a small window so the sink's open/closed maps
    // stay bounded: past the window every complete is a dup-close
    // (counter bump) — exactly the steady-state lock+hash cost.
    let mut rid = 0u64;
    let mut inst_t = time_adaptive(150.0, 200, || {
        let out = inst.route(&hot, 7, 2.0).unwrap();
        let span = trace::request_span(rid % 4096);
        rid += 1;
        let now = rid as f64 * 1e-6;
        sink.complete(span, phase::ROUTE, u32::MAX, now, now);
        sink.begin(span, phase::QUEUE, u32::MAX, now);
        sink.end(span, phase::QUEUE, now);
        black_box(out);
    });
    // Sanity: the attached registry actually counted every route.
    assert!(
        reg.snapshot(0.0).counter_sum("sched.routes") >= inst_t.len() as u64,
        "sched.routes did not count the instrumented loop"
    );
    (1e6 / bare_t.p50().max(1e-9), 1e6 / inst_t.p50().max(1e-9))
}

fn overhead(n: usize, gate: bool) {
    let mut table = Table::new("fig19_overhead", &[
        "instances", "variant", "routes_per_sec", "vs_bare",
    ]);
    println!(
        "\n-- route-path overhead: metrics registry + trace sink \
         attached vs bare, hot fleet N={n} --"
    );
    let (mut bare, mut inst) = overhead_run(n);
    let mut ratio = inst / bare.max(1e-9);
    if gate {
        // Contended-runner tolerance: re-measure (MEMSERVE_GATE_ATTEMPTS,
        // default 3) before declaring the ≤5% overhead claim dead.
        for attempt in 0..gate_attempts() {
            if ratio >= 0.95 {
                break;
            }
            println!(
                "  gate attempt {}: {ratio:.3}x — re-measuring",
                attempt + 1
            );
            let (b, i) = overhead_run(n);
            bare = b;
            inst = i;
            ratio = inst / bare.max(1e-9);
        }
    }
    table.row(vec![
        n.to_string(),
        "bare".into(),
        format!("{bare:.0}"),
        "1.00x".into(),
    ]);
    table.row(vec![
        n.to_string(),
        "instrumented".into(),
        format!("{inst:.0}"),
        format!("{ratio:.3}x"),
    ]);
    println!(
        "  bare {bare:9.0} routes/sec   instrumented {inst:9.0} \
         routes/sec   ({ratio:.3}x)"
    );
    table.finish();
    println!(
        "\nExpected shape: instrumented within 5% of bare — the route \
         path pays a handful of relaxed atomics plus one short-lived \
         mutex for the trace sink."
    );
    if gate {
        assert!(
            ratio >= 0.95,
            "MEMSERVE_FIG19_GATE: instrumented route path is {ratio:.3}x \
             bare median throughput ({inst:.0} vs {bare:.0} routes/sec), \
             below the 0.95 floor"
        );
        println!("  gate: {ratio:.3}x >= 0.95x -- pass");
    }
}

// ---------------------------------------------------------------------
// Part 2: span chains + flight recorder through the faulty-fabric sim.
// ---------------------------------------------------------------------

fn faults() {
    let mut table = Table::new("fig19_faults", &[
        "requests", "completed", "disaggregated", "chains_complete",
        "trace_events", "orphan_ends", "flight_events", "suspicions",
        "promotions",
    ]);
    println!(
        "\n-- span chains through the lossy-replication + shard-failover \
         sim: every completed request must close a full chain --"
    );
    let spec =
        WorkloadSpec::generate(WorkloadKind::Loogle, 40, 35, 2048, 4096);
    let plan = ArrivalPlan::poisson(&spec, 4.0, 35);
    let total = spec.total_requests();
    let cfg = SimConfig {
        prefill_instances: 3,
        decode_instances: 2,
        colocated_instances: 0,
        caching: true,
        milestone: DisaggMilestone::PdCaching3,
        gs_shards: 2,
        gs_replicas: 2,
        replication_drop: 0.10,
        observe: true,
        fleet: vec![FleetEvent {
            at: 5.0,
            op: FleetOp::GsFailover { shard: Some(0) },
        }],
        ..Default::default()
    };
    let rep = Simulation::new(cfg, spec, &plan).run();
    assert_eq!(
        rep.metrics.records.len(),
        total,
        "lost requests under lossy replication"
    );
    assert_eq!(rep.gs_failovers, 1, "scripted failover did not fire");
    let obs = rep.obs.as_ref().expect("observe: true fills SimReport.obs");

    let mut disagg = 0usize;
    let mut complete = 0usize;
    for r in &rep.metrics.records {
        let d = r.prefill_instance != r.decode_instance;
        disagg += d as usize;
        let span = trace::request_span(r.request_id);
        assert!(
            obs.trace.chain_complete(span, d),
            "request {} (disaggregated={d}) has an incomplete span \
             chain: {:?}",
            r.request_id,
            obs.trace.chains().get(&span)
        );
        complete += 1;
    }
    let (recorded, dropped, _dup, orphans) = obs.trace.stats();
    assert_eq!(orphans, 0, "phase ends without a matching begin");
    assert_eq!(dropped, 0, "trace ring overflowed at this scale");

    let suspicions = obs
        .flight
        .of_kind(memserve::obs::flight::kind::SUSPICION)
        .len();
    let promotions = obs
        .flight
        .of_kind(memserve::obs::flight::kind::PROMOTION)
        .len();
    assert!(!obs.flight.is_empty(), "flight recorder captured nothing");
    assert!(
        suspicions >= 1,
        "the injected crash never recorded a SUSPICION event"
    );
    assert!(
        promotions >= 1,
        "the failover never recorded a PROMOTION event"
    );
    // The folded cluster view saw the routing volume.
    let routed = obs.view.snapshot.counter_sum("sched.routes");
    assert!(
        routed >= total as u64,
        "cluster view counted {routed} routes for {total} requests"
    );

    table.row(vec![
        total.to_string(),
        rep.metrics.records.len().to_string(),
        disagg.to_string(),
        complete.to_string(),
        recorded.to_string(),
        orphans.to_string(),
        obs.flight.len().to_string(),
        suspicions.to_string(),
        promotions.to_string(),
    ]);
    println!(
        "  {complete}/{total} chains complete ({disagg} disaggregated), \
         {recorded} trace events, {} flight events \
         ({suspicions} suspicion, {promotions} promotion)",
        obs.flight.len()
    );
    table.finish();

    // Drop the artifacts next to the tables: the Chrome trace (load in
    // chrome://tracing or ui.perfetto.dev) and the flight-recorder
    // dump CI uploads alongside the bench JSON.
    if let Some(dir) = bench_json_dir() {
        if std::fs::create_dir_all(&dir).is_ok() {
            let tp = format!("{dir}/fig19_trace.json");
            match std::fs::write(&tp, obs.trace.to_chrome_json().to_string())
            {
                Ok(()) => println!("[saved {tp}]"),
                Err(e) => eprintln!("[warn] could not save trace: {e}"),
            }
        }
        if let Some(p) = obs.flight.dump_to(&dir, "fig19_flight") {
            println!("[saved {p}]");
        }
    }
    println!(
        "\nExpected shape: chains_complete = completed = requests, zero \
         orphaned ends, and the flight recorder holds the scripted \
         crash's suspicion→promotion story."
    );
}

fn main() {
    let mode = std::env::var("MEMSERVE_FIG19_MODE").unwrap_or_default();
    let n: usize = std::env::var("MEMSERVE_FIG19_N")
        .ok()
        .and_then(|s| s.trim().parse().ok())
        .unwrap_or(16)
        .max(1);
    let gate = std::env::var("MEMSERVE_FIG19_GATE").as_deref() == Ok("1");
    let all = !matches!(mode.as_str(), "overhead" | "faults");
    if all || mode == "overhead" {
        overhead(n, gate);
    }
    if all || mode == "faults" {
        faults();
    }
}
