// R5 pass: poison recovery via plock, absence handled with let-else +
// a log line, and the invariant stated as a debug_assert (loud under
// `cargo test`, graceful in release).

use crate::util::sync::{LockExt, Mutex};
use std::collections::BTreeMap;

pub fn commit(
    pending: &Mutex<BTreeMap<u64, u32>>,
    rid: u64,
) -> Option<u32> {
    let mut p = pending.plock();
    let Some(v) = p.remove(&rid) else {
        log::warn!("commit for untracked request {rid}");
        return None;
    };
    debug_assert!(v != u32::MAX, "corrupt request id {rid}");
    Some(v)
}
