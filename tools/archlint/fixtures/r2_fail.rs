// R2 FAIL: RandomState-defaulted maps in a scheduler decision path.
// Their per-process iteration order silently varies run to run, so any
// tie-break or fan-out that walks them diverges under replay.

use std::collections::{HashMap, HashSet};

pub fn pick(loads: &[(u32, u64)]) -> Option<u32> {
    let mut seen: HashSet<u32> = HashSet::new();
    let mut best: HashMap<u32, u64> = HashMap::new();
    for &(inst, load) in loads {
        if seen.insert(inst) {
            best.insert(inst, load);
        }
    }
    best.iter().min_by_key(|&(_, l)| *l).map(|(&i, _)| i)
}
