// R6 pass: every Msg match is exhaustive; deliberately-ignored
// variants are pipe-grouped by name, so adding a variant breaks the
// build at every handler that must decide about it.

pub enum Msg {
    Dispatch { req: u64 },
    Token { req: u64, tok: u32 },
    Heartbeat { seq: u64 },
}

pub fn handle(m: Option<Msg>) -> u64 {
    match m {
        Some(Msg::Dispatch { req }) => req,
        Some(Msg::Token { req, tok }) => req ^ u64::from(tok),
        Some(Msg::Heartbeat { .. }) | None => 0,
    }
}

pub fn seq_of(m: &Msg) -> u64 {
    match m {
        Msg::Heartbeat { seq } => *seq,
        Msg::Dispatch { .. } | Msg::Token { .. } => 0,
    }
}
