// R5 FAIL: panic paths in protocol code — a poisoned-lock unwrap, an
// expect on peer-controlled state, and a reachable panic!.

use std::collections::BTreeMap;
use std::sync::Mutex;

pub fn commit(pending: &Mutex<BTreeMap<u64, u32>>, rid: u64) -> u32 {
    let mut p = pending.lock().unwrap();
    let v = p.remove(&rid).expect("request tracked");
    if v == u32::MAX {
        panic!("corrupt request id {rid}");
    }
    v
}
