// R3 pass: unit access confined to `fn unit`/`fn lock_all` (ascending
// order), messages collected under the guard and sent after it drops.

use crate::util::sync::LockExt;

pub struct GsUnit {
    pub dirty: bool,
    pub outbox: Vec<u32>,
}

pub struct Plane {
    units: Vec<std::sync::Mutex<GsUnit>>,
}

impl Plane {
    fn unit(&self, s: usize) -> std::sync::MutexGuard<'_, GsUnit> {
        self.units[s].plock()
    }

    fn lock_all(&self) -> Vec<std::sync::MutexGuard<'_, GsUnit>> {
        // Ascending index order — the only multi-unit path.
        self.units.iter().map(|u| u.plock()).collect()
    }

    pub fn flush(&self, s: usize, tx: &std::sync::mpsc::Sender<u32>) {
        let drained = {
            let mut u = self.unit(s);
            std::mem::take(&mut u.outbox)
        };
        for m in drained {
            let _ = tx.send(m);
        }
    }

    pub fn sweep(&self) -> usize {
        let mut n = 0;
        for u in self.lock_all() {
            n += usize::from(u.dirty);
        }
        n
    }
}
