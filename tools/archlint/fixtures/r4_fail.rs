// R4 FAIL: an atomic Ordering use without an `// ordering:`
// justification, and a direct variant import that makes every later
// use site invisible to review.

use std::sync::atomic::{AtomicU64, Ordering::Relaxed};

pub fn bump(c: &AtomicU64) -> u64 {
    c.fetch_add(1, Relaxed);
    c.load(std::sync::atomic::Ordering::Acquire)
}
