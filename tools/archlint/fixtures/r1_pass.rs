// R1 pass: the sim takes caller-clock timestamps, and live-timing code
// receives the clock as an injected `fn() -> f64` — naming the
// function without calling it is allowed.

pub struct Stamp(pub f64);

pub fn record_arrival(now: f64) -> Stamp {
    Stamp(now)
}

pub fn timer_for_live_paths() -> fn() -> f64 {
    crate::util::clock::monotonic_secs
}

pub fn observe(timer: Option<fn() -> f64>) -> Option<f64> {
    let t0 = timer.map(|f| f());
    t0.zip(timer).map(|(t0, f)| f() - t0)
}
