// R3 FAIL: (a) the unit vector touched outside `fn unit`/`fn lock_all`
// — ad-hoc multi-unit acquisition orders can deadlock against the
// ascending `lock_all`; (b) a fabric send and a second acquisition
// while a unit guard is live.

use crate::util::sync::LockExt;

pub struct GsUnit {
    pub dirty: bool,
}

pub struct Plane {
    units: Vec<std::sync::Mutex<GsUnit>>,
}

impl Plane {
    fn unit(&self, s: usize) -> std::sync::MutexGuard<'_, GsUnit> {
        self.units[s].plock()
    }

    pub fn bad_direct_access(&self, s: usize) -> bool {
        self.units[s].plock().dirty
    }

    pub fn bad_hold_and_send(
        &self,
        s: usize,
        tx: &std::sync::mpsc::Sender<u32>,
    ) {
        let u = self.unit(s);
        if u.dirty {
            let _ = tx.send(1);
        }
        let v = self.unit(s + 1);
        let _ = v.dirty;
    }
}
