// R1 FAIL: a sim handler reading the wall clock directly. The sim runs
// on a virtual clock; an `Instant::now()` here leaks real time into
// decisions and breaks `deterministic_replay`.

pub struct Stamp(pub f64);

pub fn record_arrival() -> Stamp {
    let t0 = std::time::Instant::now();
    Stamp(t0.elapsed().as_secs_f64())
}

pub fn wall_stamp() -> f64 {
    crate::util::clock::epoch_secs()
}
