// R6 FAIL: Msg matches with catch-all arms — a new protocol variant
// routed here would be silently dropped instead of failing to compile.

pub enum Msg {
    Dispatch { req: u64 },
    Token { req: u64, tok: u32 },
    Heartbeat { seq: u64 },
}

pub fn handle(m: Option<Msg>) -> u64 {
    match m {
        Some(Msg::Dispatch { req }) => req,
        Some(other) => {
            let _ = other;
            0
        }
        None => 0,
    }
}

pub fn seq_of(m: &Msg) -> u64 {
    match m {
        Msg::Heartbeat { seq } => *seq,
        _ => 0,
    }
}
