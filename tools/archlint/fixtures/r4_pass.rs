// R4 pass: `Ordering` imported as the enum, every variant spelled at
// the use site, every use justified — one comment may head a tight
// cluster.

use std::sync::atomic::{AtomicU64, Ordering};

pub fn bump(c: &AtomicU64) -> u64 {
    // ordering: Relaxed — a statistics counter; no cross-thread
    // handoff is published through this value.
    c.fetch_add(1, Ordering::Relaxed);
    c.load(Ordering::Relaxed) // ordering: Relaxed — same counter.
}
