// R2 pass: the deterministic map/set twins from util::rng — same
// insertion history, same iteration order, every run.

use crate::util::rng::{DetMap, DetSet};

pub fn pick(loads: &[(u32, u64)]) -> Option<u32> {
    let mut seen: DetSet<u32> = DetSet::default();
    let mut best: DetMap<u32, u64> = DetMap::default();
    for &(inst, load) in loads {
        if seen.insert(inst) {
            best.insert(inst, load);
        }
    }
    best.iter().min_by_key(|&(_, l)| *l).map(|(&i, _)| i)
}
