//! archlint CLI: lint `rust/src` (or `--root PATH`) against the repo's
//! architectural rules R1–R6 and exit non-zero on any violation.
//!
//! Usage (from the repo root, as CI runs it):
//!
//! ```text
//! cargo run --manifest-path tools/archlint/Cargo.toml -- \
//!     --root rust/src --suppressions tools/archlint/suppressions.txt
//! ```

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut root = PathBuf::from("rust/src");
    let mut sup_path: Option<PathBuf> = None;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--root" => {
                let Some(v) = args.next() else {
                    eprintln!("--root needs a path");
                    return ExitCode::from(2);
                };
                root = PathBuf::from(v);
            }
            "--suppressions" => {
                let Some(v) = args.next() else {
                    eprintln!("--suppressions needs a path");
                    return ExitCode::from(2);
                };
                sup_path = Some(PathBuf::from(v));
            }
            "--help" | "-h" => {
                eprintln!(
                    "archlint [--root DIR] [--suppressions FILE]\n\
                     rules: R1 no-wall-clock, R2 no-unseeded-randomness,\n\
                     R3 lock-discipline, R4 ordering-justified,\n\
                     R5 no-panic-paths, R6 msg-exhaustive"
                );
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("unknown argument: {other}");
                return ExitCode::from(2);
            }
        }
    }

    let sup = match &sup_path {
        Some(p) => match std::fs::read_to_string(p) {
            Ok(t) => archlint::parse_suppressions(&t),
            Err(e) => {
                eprintln!("cannot read {}: {e}", p.display());
                return ExitCode::from(2);
            }
        },
        None => Vec::new(),
    };

    let violations = match archlint::lint_tree(&root) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("cannot lint {}: {e}", root.display());
            return ExitCode::from(2);
        }
    };
    let total = violations.len();
    let violations = archlint::apply_suppressions(violations, &sup);
    let suppressed = total - violations.len();

    for v in &violations {
        println!("{v}");
    }
    if suppressed > 0 {
        eprintln!(
            "archlint: {suppressed} violation(s) suppressed — the \
             suppression file is meant to stay empty; fix or revert"
        );
    }
    if violations.is_empty() {
        eprintln!("archlint: clean ({} suppressed)", suppressed);
        ExitCode::SUCCESS
    } else {
        eprintln!(
            "archlint: {} violation(s) in {}",
            violations.len(),
            root.display()
        );
        ExitCode::FAILURE
    }
}
