//! archlint — repo-specific static analysis for the MemServe tree
//! (ISSUE 10 tentpole).
//!
//! MemServe's correctness story rests on invariants no compiler checks:
//! routing must be deterministic and replay-identical across failover,
//! the sim's virtual clock must never leak into decisions, and the
//! lock-free data plane's relaxed atomics must carry their reasoning in
//! the source. This tool enforces those invariants as named,
//! individually-testable rules over `rust/src/`:
//!
//! * **R1 no-wall-clock** — `Instant::now(` / `SystemTime::now(` /
//!   `util::clock::{monotonic_secs,epoch_secs}(` calls only in
//!   allow-listed live-server modules (`server/`, `runtime/`,
//!   `net/fabric.rs`, `main.rs`, `util/bench.rs`, `util/logging.rs`,
//!   `util/clock.rs`). Everything else takes caller-clock timestamps or
//!   an injected `fn() -> f64` timer (passing the fn *by name* is fine;
//!   *calling* it is what leaks).
//! * **R2 no-unseeded-randomness** — `thread_rng` / `rand::` nowhere;
//!   `RandomState`-defaulted `HashMap::new` / `HashSet::new` /
//!   `with_capacity` nowhere in decision-path dirs (`scheduler/`,
//!   `elastic/`, `replica/`, `sim/`, `mempool/`, `server/`) — use
//!   `util::rng::{DetMap, DetSet}` or an explicit deterministic hasher.
//! * **R3 lock-discipline** (`server/data_plane.rs`,
//!   `server/leader.rs`) — (a) the unit vector is touched only inside
//!   `fn unit` / `fn lock_all` (plus `.len()`), so multi-unit
//!   acquisition can only happen via ascending `lock_all`; (b) while a
//!   let-bound unit guard is live, no further `self.unit(`/`lock_all(`
//!   acquisition and no `.send(` — collect messages under the lock,
//!   send after the guard drops.
//! * **R4 ordering-justified** — every atomic `Ordering::{Relaxed,
//!   Acquire, Release, AcqRel, SeqCst}` token carries an `// ordering:`
//!   comment on the same line or within the three lines above (a
//!   justified line extends cover to immediately-following uses, so one
//!   comment can head a tight cluster). Importing a variant directly
//!   (`use ...Ordering::Relaxed`) is banned — it hides the choice at
//!   the use site. `std::cmp::Ordering` is untouched (different
//!   variants).
//! * **R5 no-panic-paths** — `.unwrap()` / `.expect(` / `panic!` /
//!   `unreachable!` / `todo!` / `unimplemented!` banned in non-test
//!   `server/`, `replica/`, `net/` code. `debug_assert!` is the
//!   sanctioned invariant check (loud under `cargo test`, graceful in
//!   release); poisoned locks recover via `util::sync::{plock, pread,
//!   pwrite}`.
//! * **R6 msg-exhaustive** — a `match` whose arms name `Msg::` variants
//!   must not have a catch-all arm (`_`, a bare binding, `Some(_)`,
//!   `Some(binding)`): new protocol variants must fail compilation at
//!   every handler instead of being silently dropped.
//!
//! **What the lexer is.** A purpose-built scanner, not a Rust parser:
//! it strips comments and string/char literals (preserving line
//! structure), tracks `#[cfg(...test...)]`-gated regions by brace
//! depth, and then runs token-level rules. It understands raw strings,
//! nested block comments, and lifetimes-vs-char-literals, which is
//! enough for this tree. It does not expand macros and does not resolve
//! paths — rules are written so that the cheap lexical approximation
//! errs on the side of firing (and the golden fixtures in
//! `src/lib.rs::tests` pin each rule's fire/pass behavior).

use std::fmt;
use std::fs;
use std::io;
use std::path::Path;

/// One rule hit. `file` is the path relative to the lint root, `line`
/// is 1-based.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    pub rule: &'static str,
    pub file: String,
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: [{}] {}",
            self.file, self.line, self.rule, self.msg
        )
    }
}

/// One source line after lexing: comment/string-stripped code, whether
/// it sits in a `#[cfg(test)]`-gated region, and whether a comment on
/// (or spanning) this line contains the `ordering:` marker.
struct LineInfo {
    code: String,
    in_test: bool,
    ordering_comment: bool,
}

struct Prepared {
    rel: String,
    lines: Vec<LineInfo>,
}

// ---------------------------------------------------------------------
// Lexer: strip comments + string/char literals, preserving lines.
// ---------------------------------------------------------------------

#[derive(PartialEq, Clone, Copy)]
enum LexState {
    Code,
    LineComment,
    BlockComment(u32),
    Str,
    RawStr(usize),
}

fn is_ident_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Returns per-line (code, comment-text) pairs.
fn strip(src: &str) -> Vec<(String, String)> {
    let b: Vec<char> = src.chars().collect();
    let mut out = Vec::new();
    let mut code = String::new();
    let mut com = String::new();
    let mut st = LexState::Code;
    let mut i = 0usize;
    while i < b.len() {
        let c = b[i];
        if c == '\n' {
            if st == LexState::LineComment {
                st = LexState::Code;
            }
            out.push((
                std::mem::take(&mut code),
                std::mem::take(&mut com),
            ));
            i += 1;
            continue;
        }
        match st {
            LexState::Code => {
                let next = b.get(i + 1).copied();
                if c == '/' && next == Some('/') {
                    st = LexState::LineComment;
                    i += 2;
                    continue;
                }
                if c == '/' && next == Some('*') {
                    st = LexState::BlockComment(1);
                    i += 2;
                    continue;
                }
                let prev_ident =
                    i > 0 && is_ident_char(b[i - 1]);
                if !prev_ident && (c == 'r' || c == 'b') {
                    // b"..." byte string
                    if c == 'b' && next == Some('"') {
                        code.push_str("b\"");
                        st = LexState::Str;
                        i += 2;
                        continue;
                    }
                    // r"...", r#"..."#, br"...", br#"..."#
                    let rpos = if c == 'r' {
                        Some(i)
                    } else if next == Some('r') {
                        Some(i + 1)
                    } else {
                        None
                    };
                    if let Some(rpos) = rpos {
                        let mut j = rpos + 1;
                        let mut hashes = 0usize;
                        while b.get(j) == Some(&'#') {
                            hashes += 1;
                            j += 1;
                        }
                        if b.get(j) == Some(&'"') {
                            code.push_str("r\"");
                            st = LexState::RawStr(hashes);
                            i = j + 1;
                            continue;
                        }
                    }
                }
                if c == '"' {
                    code.push('"');
                    st = LexState::Str;
                    i += 1;
                    continue;
                }
                if c == '\'' {
                    // Char literal vs lifetime.
                    if b.get(i + 1) == Some(&'\\') {
                        let mut j = i + 2;
                        while j < b.len()
                            && b[j] != '\''
                            && b[j] != '\n'
                        {
                            j += 1;
                        }
                        code.push_str("' '");
                        i = j + 1;
                        continue;
                    }
                    if b.get(i + 2) == Some(&'\'')
                        && b.get(i + 1) != Some(&'\'')
                        && b.get(i + 1) != Some(&'\n')
                    {
                        code.push_str("' '");
                        i += 3;
                        continue;
                    }
                    // Lifetime: keep the tick, scanning continues.
                    code.push('\'');
                    i += 1;
                    continue;
                }
                code.push(c);
                i += 1;
            }
            LexState::LineComment => {
                com.push(c);
                i += 1;
            }
            LexState::BlockComment(d) => {
                let next = b.get(i + 1).copied();
                if c == '*' && next == Some('/') {
                    if d == 1 {
                        st = LexState::Code;
                    } else {
                        st = LexState::BlockComment(d - 1);
                    }
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    st = LexState::BlockComment(d + 1);
                    i += 2;
                } else {
                    com.push(c);
                    i += 1;
                }
            }
            LexState::Str => {
                if c == '\\' {
                    // Keep the following newline visible to the line
                    // splitter (string continuation).
                    if b.get(i + 1) == Some(&'\n') {
                        i += 1;
                    } else {
                        i += 2;
                    }
                } else if c == '"' {
                    code.push('"');
                    st = LexState::Code;
                    i += 1;
                } else {
                    code.push(' ');
                    i += 1;
                }
            }
            LexState::RawStr(h) => {
                if c == '"' {
                    let closes = (1..=h)
                        .all(|k| b.get(i + k) == Some(&'#'));
                    if closes {
                        code.push('"');
                        st = LexState::Code;
                        i += 1 + h;
                        continue;
                    }
                }
                code.push(' ');
                i += 1;
            }
        }
    }
    out.push((code, com));
    out
}

/// Mark lines inside `#[cfg(...test...)]`-gated items (a gated mod,
/// impl, or fn and its whole body) as test code.
fn mark_test_regions(lines: &mut [LineInfo]) {
    let mut depth: i64 = 0;
    let mut pending = false;
    // Stack of depths at which a skip region was entered (supports the
    // uncommon nested-gated-item case).
    let mut skip_until: Vec<i64> = Vec::new();
    for li in lines.iter_mut() {
        if !skip_until.is_empty() {
            li.in_test = true;
        }
        let code = li.code.clone();
        // Attribute detection is line-based: the gate attributes this
        // tree uses (`#[cfg(test)]`, `#[cfg(all(test, loom))]`, ...)
        // never span lines.
        if let Some(p) = code.find("#[cfg(") {
            let rest = &code[p..];
            let end = rest.find(']').unwrap_or(rest.len());
            let attr = &rest[..end];
            // Gated-out-of-tier-1 regions: test mods and loom-only
            // items. `#[cfg(not(loom))]` is the *normal* build — lint.
            let loom_only = attr.contains("loom")
                && !attr.contains("not(loom)");
            if attr.contains("test") || loom_only {
                pending = true;
                li.in_test = true;
            }
        }
        for c in code.chars() {
            match c {
                '{' => {
                    depth += 1;
                    if pending {
                        pending = false;
                        skip_until.push(depth - 1);
                        li.in_test = true;
                    }
                }
                '}' => {
                    depth -= 1;
                    if skip_until.last() == Some(&depth) {
                        skip_until.pop();
                    }
                }
                ';' => {
                    // `#[cfg(test)] use ...;` — item without a body.
                    if pending && skip_until.is_empty() {
                        pending = false;
                    }
                }
                _ => {}
            }
        }
        if !skip_until.is_empty() {
            li.in_test = true;
        }
    }
}

fn prepare(rel: &str, src: &str) -> Prepared {
    let mut lines: Vec<LineInfo> = strip(src)
        .into_iter()
        .map(|(code, com)| LineInfo {
            ordering_comment: com.contains("ordering:"),
            code,
            in_test: false,
        })
        .collect();
    mark_test_regions(&mut lines);
    Prepared {
        rel: rel.to_string(),
        lines,
    }
}

/// Find `needle` in `hay` at token boundaries: the char before the
/// match must not be an identifier char (so `match_hit(` does not match
/// `match`, and `fetch_or(` does not match `or(`). Returns byte
/// offsets of match starts.
fn token_find(hay: &str, needle: &str) -> Vec<usize> {
    let mut out = Vec::new();
    let mut from = 0usize;
    while let Some(p) = hay[from..].find(needle) {
        let at = from + p;
        let ok_before = at == 0
            || !is_ident_char(
                hay[..at].chars().next_back().unwrap_or(' '),
            );
        if ok_before {
            out.push(at);
        }
        from = at + needle.len().max(1);
    }
    out
}

// ---------------------------------------------------------------------
// Rules
// ---------------------------------------------------------------------

const R1_ALLOW: &[&str] = &[
    "server/",
    "runtime/",
    "net/fabric.rs",
    "main.rs",
    "bin/",
    "util/bench.rs",
    "util/logging.rs",
    "util/clock.rs",
];

const R1_TOKENS: &[&str] = &[
    "Instant::now(",
    "SystemTime::now(",
    "monotonic_secs(",
    "epoch_secs(",
];

fn rule_r1(p: &Prepared, out: &mut Vec<Violation>) {
    if R1_ALLOW.iter().any(|a| p.rel.starts_with(a)) {
        return;
    }
    for (n, li) in p.lines.iter().enumerate() {
        if li.in_test {
            continue;
        }
        for tok in R1_TOKENS {
            if !token_find(&li.code, tok).is_empty() {
                out.push(Violation {
                    rule: "R1",
                    file: p.rel.clone(),
                    line: n + 1,
                    msg: format!(
                        "wall-clock read `{}` outside the live-server \
                         allow list; take a caller timestamp or an \
                         injected `fn() -> f64` timer",
                        tok.trim_end_matches('(')
                    ),
                });
            }
        }
    }
}

const R2_DECISION_DIRS: &[&str] = &[
    "scheduler/",
    "elastic/",
    "replica/",
    "sim/",
    "mempool/",
    "server/",
];

const R2_GLOBAL_TOKENS: &[&str] =
    &["thread_rng(", "rand::", "RandomState::new("];

const R2_MAP_TOKENS: &[&str] = &[
    "HashMap::new(",
    "HashSet::new(",
    "HashMap::with_capacity(",
    "HashSet::with_capacity(",
];

fn rule_r2(p: &Prepared, out: &mut Vec<Violation>) {
    let in_decision_dir =
        R2_DECISION_DIRS.iter().any(|d| p.rel.starts_with(d));
    for (n, li) in p.lines.iter().enumerate() {
        if li.in_test {
            continue;
        }
        if p.rel != "util/rng.rs" {
            for tok in R2_GLOBAL_TOKENS {
                if !token_find(&li.code, tok).is_empty() {
                    out.push(Violation {
                        rule: "R2",
                        file: p.rel.clone(),
                        line: n + 1,
                        msg: format!(
                            "unseeded randomness `{}`; all randomness \
                             flows from util::rng seeds",
                            tok.trim_end_matches('(')
                        ),
                    });
                }
            }
        }
        if in_decision_dir {
            for tok in R2_MAP_TOKENS {
                if !token_find(&li.code, tok).is_empty() {
                    out.push(Violation {
                        rule: "R2",
                        file: p.rel.clone(),
                        line: n + 1,
                        msg: format!(
                            "`{}` defaults to RandomState (per-process \
                             iteration order) in a decision path; use \
                             util::rng::DetMap/DetSet",
                            tok.trim_end_matches('(')
                        ),
                    });
                }
            }
        }
    }
}

const R3_FILES: &[&str] =
    &["server/data_plane.rs", "server/leader.rs"];

/// Byte spans (line ranges) of `fn unit...` / `fn lock_all...` bodies,
/// where direct `.units` access is sanctioned.
fn fn_body_lines(
    p: &Prepared,
    fn_tokens: &[&str],
) -> Vec<(usize, usize)> {
    let mut spans = Vec::new();
    let mut n = 0usize;
    while n < p.lines.len() {
        let code = &p.lines[n].code;
        let hit = fn_tokens
            .iter()
            .any(|t| !token_find(code, t).is_empty());
        if !hit {
            n += 1;
            continue;
        }
        // Walk from the signature to the body's matching close brace.
        let start = n;
        let mut depth = 0i64;
        let mut opened = false;
        let mut m = n;
        'outer: while m < p.lines.len() {
            for c in p.lines[m].code.chars() {
                match c {
                    '{' => {
                        depth += 1;
                        opened = true;
                    }
                    '}' => {
                        depth -= 1;
                        if opened && depth == 0 {
                            break 'outer;
                        }
                    }
                    _ => {}
                }
            }
            m += 1;
        }
        spans.push((start, m.min(p.lines.len() - 1)));
        n = m + 1;
    }
    spans
}

fn rule_r3(p: &Prepared, out: &mut Vec<Violation>) {
    if !R3_FILES.contains(&p.rel.as_str()) {
        return;
    }
    // R3a: `.units` confined to `fn unit` / `fn lock_all` (+ `.len()`).
    if p.rel == "server/data_plane.rs" {
        let allowed = fn_body_lines(
            p,
            &["fn unit(", "fn unit_mut(", "fn lock_all("],
        );
        for (n, li) in p.lines.iter().enumerate() {
            if li.in_test {
                continue;
            }
            for (at, _) in li.code.match_indices(".units") {
                let rest = &li.code[at + ".units".len()..];
                if rest.starts_with(".len()") {
                    continue;
                }
                // Field declaration / struct literal (`units:`) has no
                // leading dot, so any `.units` here is an access.
                let sanctioned = allowed
                    .iter()
                    .any(|&(a, b)| n >= a && n <= b);
                if !sanctioned {
                    out.push(Violation {
                        rule: "R3",
                        file: p.rel.clone(),
                        line: n + 1,
                        msg: "direct unit-vector access outside \
                              `fn unit`/`fn lock_all`; multi-unit \
                              acquisition must go through ascending \
                              lock_all"
                            .to_string(),
                    });
                }
            }
        }
    }
    // R3b: while a let-bound unit guard is live — no second
    // acquisition, no `.send(`.
    let mut depth: i64 = 0;
    // (binding name, depth at which it was introduced)
    let mut guards: Vec<(String, i64)> = Vec::new();
    for (n, li) in p.lines.iter().enumerate() {
        if li.in_test {
            // Keep depth bookkeeping but never track/flag in tests.
            for c in li.code.chars() {
                match c {
                    '{' => depth += 1,
                    '}' => depth -= 1,
                    _ => {}
                }
            }
            guards.retain(|g| g.1 <= depth);
            continue;
        }
        let code = &li.code;
        let acquisitions = code.matches(".unit(").count()
            + code.matches(".lock_all(").count();
        let is_let_guard = acquisitions > 0
            && code.trim_start().starts_with("let ");
        if !guards.is_empty() && acquisitions > 0 {
            out.push(Violation {
                rule: "R3",
                file: p.rel.clone(),
                line: n + 1,
                msg: "unit acquisition while another unit guard is \
                      live; ascending multi-unit locking only via \
                      lock_all"
                    .to_string(),
            });
        } else if acquisitions >= 2 {
            out.push(Violation {
                rule: "R3",
                file: p.rel.clone(),
                line: n + 1,
                msg: "two unit acquisitions in one statement; use \
                      lock_all"
                    .to_string(),
            });
        }
        if !guards.is_empty() && code.contains(".send(") {
            out.push(Violation {
                rule: "R3",
                file: p.rel.clone(),
                line: n + 1,
                msg: "send while a unit lock is held; collect \
                      messages under the guard and send after it \
                      drops"
                    .to_string(),
            });
        }
        // Guard births/deaths after the line's checks: the binding
        // itself is the first acquisition, not a nested one.
        if is_let_guard {
            let name = code
                .trim_start()
                .trim_start_matches("let ")
                .trim_start_matches("mut ")
                .chars()
                .take_while(|&c| is_ident_char(c))
                .collect::<String>();
            if !name.is_empty() {
                guards.push((name, depth));
            }
        }
        for at in token_find(code, "drop(") {
            let arg: String = code[at + "drop(".len()..]
                .chars()
                .take_while(|&c| is_ident_char(c))
                .collect();
            guards.retain(|g| g.0 != arg);
        }
        for c in code.chars() {
            match c {
                '{' => depth += 1,
                '}' => depth -= 1,
                _ => {}
            }
        }
        guards.retain(|g| g.1 <= depth);
    }
}

const R4_VARIANTS: &[&str] = &[
    "Ordering::Relaxed",
    "Ordering::Acquire",
    "Ordering::Release",
    "Ordering::AcqRel",
    "Ordering::SeqCst",
];

fn rule_r4(p: &Prepared, out: &mut Vec<Violation>) {
    // A justified line covers itself and the 3 lines below; a covered
    // use extends cover one further line so one comment can head a
    // tight cluster of uses.
    let mut cover_until: i64 = -1;
    for (n, li) in p.lines.iter().enumerate() {
        if li.ordering_comment {
            cover_until = cover_until.max(n as i64 + 3);
        }
        if li.in_test {
            continue;
        }
        let uses = R4_VARIANTS
            .iter()
            .map(|v| token_find(&li.code, v).len())
            .sum::<usize>();
        if uses == 0 {
            continue;
        }
        let is_import = li.code.trim_start().starts_with("use ")
            || li.code.trim_start().starts_with("pub use ");
        if is_import {
            out.push(Violation {
                rule: "R4",
                file: p.rel.clone(),
                line: n + 1,
                msg: "importing an atomic Ordering variant directly \
                      hides the choice at the use site; import \
                      `Ordering` and spell `Ordering::X` where used"
                    .to_string(),
            });
            continue;
        }
        if (n as i64) <= cover_until {
            // Chained cover: this justified use lets an immediately
            // following use share the comment.
            cover_until = cover_until.max(n as i64 + 1);
            continue;
        }
        out.push(Violation {
            rule: "R4",
            file: p.rel.clone(),
            line: n + 1,
            msg: "atomic Ordering use without an `// ordering:` \
                  justification comment (same line or the 3 lines \
                  above)"
                .to_string(),
        });
    }
}

const R5_DIRS: &[&str] = &["server/", "replica/", "net/"];

const R5_TOKENS: &[&str] = &[
    ".unwrap()",
    ".expect(",
    "panic!",
    "unreachable!",
    "todo!",
    "unimplemented!",
];

fn rule_r5(p: &Prepared, out: &mut Vec<Violation>) {
    if !R5_DIRS.iter().any(|d| p.rel.starts_with(d)) {
        return;
    }
    for (n, li) in p.lines.iter().enumerate() {
        if li.in_test {
            continue;
        }
        for tok in R5_TOKENS {
            let hits = if tok.starts_with('.') {
                // Method tokens: plain substring (preceded by an
                // expression, not an identifier boundary).
                li.code.matches(tok).count()
            } else {
                token_find(&li.code, tok).len()
            };
            if hits > 0 {
                out.push(Violation {
                    rule: "R5",
                    file: p.rel.clone(),
                    line: n + 1,
                    msg: format!(
                        "`{tok}` in a protocol path; recover (plock/\
                         pread/pwrite, let-else, log) or degrade — \
                         `debug_assert!` is the invariant escape hatch"
                    ),
                });
            }
        }
    }
}

/// Arm-pattern extraction for R6: walk a `match` body, returning
/// `(pattern_text, line)` for each depth-1 arm.
fn match_arms(
    p: &Prepared,
    start_line: usize,
    start_col: usize,
) -> Option<(Vec<(String, usize)>, usize)> {
    // Phase 1: find the body's opening brace after the scrutinee.
    let mut n = start_line;
    let mut col = start_col;
    let mut paren: i64 = 0;
    let mut open: Option<(usize, usize)> = None;
    'find: while n < p.lines.len() {
        let code = &p.lines[n].code;
        for (ci, c) in code.char_indices().skip(col) {
            match c {
                '(' | '[' => paren += 1,
                ')' | ']' => paren -= 1,
                '{' if paren == 0 => {
                    open = Some((n, ci));
                    break 'find;
                }
                _ => {}
            }
        }
        n += 1;
        col = 0;
    }
    let (bn, bc) = open?;
    // Phase 2: split depth-1 arms. A `=>` at brace depth 1 outside an
    // arm body is always the arm separator (patterns cannot contain
    // `=>`); everything inside bodies and nested braces is skipped.
    let mut arms: Vec<(String, usize)> = Vec::new();
    let mut depth: i64 = 1;
    let mut buf = String::new();
    let mut buf_line = bn;
    let mut in_body = false;
    let mut n = bn;
    let mut col = bc + 1;
    while n < p.lines.len() {
        let code = &p.lines[n].code;
        let chars: Vec<char> = code.chars().collect();
        let mut ci = col;
        while ci < chars.len() {
            let c = chars[ci];
            match c {
                '{' => {
                    depth += 1;
                    if depth == 2 && !in_body {
                        // Struct pattern `Msg::X { .. }` inside the
                        // arm pattern — keep the brace, contents are
                        // irrelevant to classification.
                        buf.push(c);
                    }
                }
                '}' => {
                    depth -= 1;
                    if depth == 0 {
                        return Some((arms, n));
                    }
                    if depth == 1 {
                        if in_body {
                            // Block arm body closed.
                            in_body = false;
                            buf.clear();
                            buf_line = n;
                        } else {
                            buf.push(c);
                        }
                    }
                }
                ',' if depth == 1 => {
                    if in_body {
                        in_body = false;
                    }
                    buf.clear();
                    buf_line = n;
                }
                '=' if depth == 1
                    && !in_body
                    && chars.get(ci + 1) == Some(&'>') =>
                {
                    let pat = buf.trim().to_string();
                    if !pat.is_empty() {
                        arms.push((pat, buf_line + 1));
                    }
                    buf.clear();
                    in_body = true;
                    ci += 1;
                }
                _ => {
                    if depth == 1 && !in_body {
                        if buf.is_empty()
                            && !c.is_whitespace()
                        {
                            buf_line = n;
                        }
                        buf.push(c);
                    }
                }
            }
            ci += 1;
        }
        if depth == 1 && !in_body && !buf.is_empty() {
            buf.push(' ');
        }
        n += 1;
        col = 0;
    }
    Some((arms, p.lines.len().saturating_sub(1)))
}

/// Is this arm pattern a catch-all that would silently swallow new
/// `Msg` variants?
fn is_catch_all(pat: &str) -> bool {
    // Strip a match guard: the pattern part precedes ` if `.
    let pat = match pat.find(" if ") {
        Some(k) => pat[..k].trim(),
        None => pat.trim(),
    };
    if pat == "_" || pat == ".." {
        return true;
    }
    let bare_binding = !pat.is_empty()
        && pat
            .chars()
            .next()
            .map(|c| c.is_ascii_lowercase() || c == '_')
            .unwrap_or(false)
        && pat.chars().all(is_ident_char);
    if bare_binding {
        return true;
    }
    for wrap in ["Some(", "Ok("] {
        if let Some(inner) = pat
            .strip_prefix(wrap)
            .and_then(|r| r.strip_suffix(')'))
        {
            return is_catch_all(inner);
        }
    }
    false
}

fn rule_r6(p: &Prepared, out: &mut Vec<Violation>) {
    for n in 0..p.lines.len() {
        if p.lines[n].in_test {
            continue;
        }
        for at in token_find(&p.lines[n].code, "match ") {
            let Some((arms, _)) =
                match_arms(p, n, at + "match ".len())
            else {
                continue;
            };
            let is_msg_match =
                arms.iter().any(|(pat, _)| pat.contains("Msg::"));
            if !is_msg_match {
                continue;
            }
            for (pat, line) in &arms {
                if is_catch_all(pat) {
                    out.push(Violation {
                        rule: "R6",
                        file: p.rel.clone(),
                        line: *line,
                        msg: format!(
                            "catch-all arm `{pat}` in a Msg match; \
                             enumerate the ignored variants so new \
                             protocol messages fail compilation here"
                        ),
                    });
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// Driver
// ---------------------------------------------------------------------

/// Lint one source file given its path relative to the lint root.
pub fn lint_source(rel: &str, src: &str) -> Vec<Violation> {
    let rel = rel.replace('\\', "/");
    let p = prepare(&rel, src);
    let mut out = Vec::new();
    rule_r1(&p, &mut out);
    rule_r2(&p, &mut out);
    rule_r3(&p, &mut out);
    rule_r4(&p, &mut out);
    rule_r5(&p, &mut out);
    rule_r6(&p, &mut out);
    out.sort_by(|a, b| {
        (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule))
    });
    out
}

fn walk(dir: &Path, files: &mut Vec<std::path::PathBuf>) -> io::Result<()> {
    let mut entries: Vec<_> =
        fs::read_dir(dir)?.collect::<Result<_, _>>()?;
    entries.sort_by_key(|e| e.path());
    for e in entries {
        let path = e.path();
        if path.is_dir() {
            walk(&path, files)?;
        } else if path.extension().and_then(|x| x.to_str())
            == Some("rs")
        {
            files.push(path);
        }
    }
    Ok(())
}

/// Lint every `.rs` file under `root` (paths reported relative to it).
pub fn lint_tree(root: &Path) -> io::Result<Vec<Violation>> {
    let mut files = Vec::new();
    walk(root, &mut files)?;
    let mut out = Vec::new();
    for f in files {
        let rel = f
            .strip_prefix(root)
            .unwrap_or(&f)
            .to_string_lossy()
            .replace('\\', "/");
        let src = fs::read_to_string(&f)?;
        out.extend(lint_source(&rel, &src));
    }
    Ok(out)
}

/// Suppression file: one `path.rs:RULE` per line, `#` comments. The
/// repo policy is that this stays EMPTY (the only sanctioned exception
/// — the `runtime/executor.rs` unsafe allow — is a compiler-level
/// `#[allow(unsafe_code)]`, not an archlint suppression); the
/// mechanism exists so an emergency suppression is a reviewed,
/// greppable one-liner instead of a rule edit.
pub fn parse_suppressions(text: &str) -> Vec<(String, String)> {
    text.lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .filter_map(|l| {
            let (f, r) = l.rsplit_once(':')?;
            Some((f.trim().to_string(), r.trim().to_string()))
        })
        .collect()
}

pub fn apply_suppressions(
    violations: Vec<Violation>,
    sup: &[(String, String)],
) -> Vec<Violation> {
    violations
        .into_iter()
        .filter(|v| {
            !sup.iter()
                .any(|(f, r)| *f == v.file && *r == v.rule)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_of(violations: &[Violation]) -> Vec<&'static str> {
        let mut r: Vec<&'static str> =
            violations.iter().map(|v| v.rule).collect();
        r.dedup();
        r
    }

    /// Each rule's golden FAIL fixture must fire that rule, and its
    /// pass fixture must be completely clean — rules without failing
    /// fixtures don't count (ISSUE 10 acceptance).
    #[test]
    fn golden_fixtures_fire_and_pass() {
        let cases: &[(&str, &str, &str, &str)] = &[
            (
                "R1",
                "sim/cluster.rs",
                include_str!("../fixtures/r1_fail.rs"),
                include_str!("../fixtures/r1_pass.rs"),
            ),
            (
                "R2",
                "scheduler/router.rs",
                include_str!("../fixtures/r2_fail.rs"),
                include_str!("../fixtures/r2_pass.rs"),
            ),
            (
                "R3",
                "server/data_plane.rs",
                include_str!("../fixtures/r3_fail.rs"),
                include_str!("../fixtures/r3_pass.rs"),
            ),
            (
                "R4",
                "mempool/index.rs",
                include_str!("../fixtures/r4_fail.rs"),
                include_str!("../fixtures/r4_pass.rs"),
            ),
            (
                "R5",
                "server/leader.rs",
                include_str!("../fixtures/r5_fail.rs"),
                include_str!("../fixtures/r5_pass.rs"),
            ),
            (
                "R6",
                "server/instance.rs",
                include_str!("../fixtures/r6_fail.rs"),
                include_str!("../fixtures/r6_pass.rs"),
            ),
        ];
        for (rule, path, fail_src, pass_src) in cases {
            let fails = lint_source(path, fail_src);
            assert!(
                fails.iter().any(|v| v.rule == *rule),
                "{rule} FAIL fixture did not fire; got {:?}",
                rules_of(&fails)
            );
            let passes = lint_source(path, pass_src);
            assert!(
                passes.is_empty(),
                "{rule} pass fixture not clean: {:?}",
                passes
                    .iter()
                    .map(|v| v.to_string())
                    .collect::<Vec<_>>()
            );
        }
    }

    /// The live tree is clean: zero violations across rust/src, with
    /// the committed suppression file EMPTY.
    #[test]
    fn live_tree_is_clean() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("../../rust/src");
        let sup_text = std::fs::read_to_string(
            Path::new(env!("CARGO_MANIFEST_DIR"))
                .join("suppressions.txt"),
        )
        .unwrap_or_default();
        let sup = parse_suppressions(&sup_text);
        assert!(
            sup.is_empty(),
            "suppression file must stay empty; found {sup:?}"
        );
        let violations =
            lint_tree(&root).expect("walk rust/src");
        assert!(
            violations.is_empty(),
            "live tree has {} violation(s):\n{}",
            violations.len(),
            violations
                .iter()
                .map(|v| v.to_string())
                .collect::<Vec<_>>()
                .join("\n")
        );
    }

    #[test]
    fn lexer_strips_strings_comments_and_char_literals() {
        let src = "let a = \"Instant::now()\"; // Instant::now()\n\
                   let b = 'x'; let c: &'static str = r#\"panic!\"#;\n\
                   /* Ordering::SeqCst */ let d = 1;\n";
        let v = lint_source("sim/x.rs", src);
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn cfg_test_regions_are_exempt() {
        let src = "\
pub fn live() {}
#[cfg(test)]
mod tests {
    #[test]
    fn t() {
        let _t = std::time::Instant::now();
        let m: std::collections::HashMap<u32, u32> =
            HashMap::new();
        m.get(&0).unwrap();
    }
}
";
        let v = lint_source("scheduler/router.rs", src);
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn ordering_comment_covers_a_cluster() {
        let src = "\
fn f(a: &AtomicU64, b: &AtomicU64) {
    // ordering: Relaxed — counters only, no cross-thread handoff.
    a.store(1, Ordering::Relaxed);
    b.store(2, Ordering::Relaxed);
}
";
        let v = lint_source("obs/registry.rs", src);
        assert!(v.is_empty(), "{v:?}");
        let bare = "\
fn f(a: &AtomicU64) {
    a.store(1, Ordering::Relaxed);
}
";
        let v = lint_source("obs/registry.rs", bare);
        assert_eq!(rules_of(&v), vec!["R4"]);
    }

    #[test]
    fn suppressions_filter_exact_file_rule_pairs() {
        let sup = parse_suppressions(
            "# comment\nserver/leader.rs:R5\n",
        );
        let v = vec![
            Violation {
                rule: "R5",
                file: "server/leader.rs".into(),
                line: 1,
                msg: String::new(),
            },
            Violation {
                rule: "R4",
                file: "server/leader.rs".into(),
                line: 2,
                msg: String::new(),
            },
        ];
        let left = apply_suppressions(v, &sup);
        assert_eq!(left.len(), 1);
        assert_eq!(left[0].rule, "R4");
    }

    #[test]
    fn msg_match_with_pipe_grouped_ignores_is_clean() {
        let src = "\
fn handle(m: Msg) {
    match m {
        Msg::Token { req, tok } => eat(req, tok),
        Msg::Heartbeat { .. } | Msg::Shutdown => {}
    }
}
";
        let v = lint_source("server/instance.rs", src);
        assert!(v.is_empty(), "{v:?}");
    }
}
