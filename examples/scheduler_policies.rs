//! Global-scheduler policy comparison (paper §6, Table 6 / Fig 15
//! preview) on the discrete-event simulator: least-load vs session-id vs
//! prompt-tree routing over a 3P1D cluster serving LooGLE-like sessions.
//!
//!     cargo run --release --example scheduler_policies

use memserve::scheduler::PolicyKind;
use memserve::sim::{SimConfig, Simulation};
use memserve::util::bench::Table;
use memserve::workload::{ArrivalPlan, WorkloadKind, WorkloadSpec};

fn main() {
    memserve::util::logging::init();
    let mut table = Table::new("scheduler_policies", &[
        "policy", "share_ratio", "cached_ratio", "ttft_mean_s",
        "ttft_p99_s", "jct_mean_s",
    ]);
    for &share in &[1usize, 2, 4] {
        // "Share ratio" (paper Fig 15): duplicate the session set so the
        // same documents arrive share× times across different sessions.
        let base = WorkloadSpec::generate(
            WorkloadKind::Loogle, 20, 7, 2048, 4096);
        let mut spec = base.clone();
        for r in 1..share {
            let mut dup = base.clone();
            for s in &mut dup.sessions {
                s.id += (r * 1000) as u64;
            }
            spec.sessions.extend(dup.sessions);
        }
        let plan = ArrivalPlan::poisson(&spec, 12.0, 7);
        for policy in [
            PolicyKind::LeastLoad,
            PolicyKind::SessionId,
            PolicyKind::PromptTree,
        ] {
            let cfg = SimConfig {
                prefill_instances: 3,
                decode_instances: 1,
                policy,
                ..Default::default()
            };
            let rep = Simulation::new(cfg, spec.clone(), &plan).run();
            let ttft = rep.metrics.ttft();
            table.row(vec![
                policy.name().into(),
                share.to_string(),
                format!("{:.3}", rep.metrics.mean_cached_ratio()),
                format!("{:.4}", ttft.mean),
                format!("{:.4}", ttft.p99),
                format!("{:.4}", rep.metrics.jct().mean),
            ]);
        }
    }
    table.finish();
    println!(
        "\nExpected shape (paper Fig 15): prompt_tree cuts TTFT most, and \
         its advantage grows with the share ratio (inter-session reuse \
         that session_id routing cannot see)."
    );
}
