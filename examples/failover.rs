//! Failure handling demo (paper §4.4): heartbeats, failure detection,
//! membership broadcast, and request re-routing around a dead instance —
//! on the live PJRT serving path.
//!
//!     make artifacts && cargo run --release --example failover

use std::sync::Arc;
use std::time::Duration;

use memserve::config::Config;
use memserve::engine::{DisaggMilestone, SamplingParams};
use memserve::runtime::ModelRuntime;
use memserve::server::{ServeCluster, ServeOptions};

fn toks(n: usize, seed: u32) -> Vec<u32> {
    (0..n as u32)
        .map(|i| (i.wrapping_mul(2654435761).wrapping_add(seed)) % 2048)
        .collect()
}

fn main() -> anyhow::Result<()> {
    memserve::util::logging::init();
    let mut cfg = Config::default();
    cfg.cluster.prefill_instances = 0;
    cfg.cluster.decode_instances = 0;
    cfg.cluster.colocated_instances = 3;
    cfg.cluster.heartbeat_ms = 25.0;
    cfg.cluster.heartbeat_misses = 3;

    println!("loading runtime...");
    let runtime = Arc::new(ModelRuntime::load("artifacts")?);
    let cluster = ServeCluster::start(
        ServeOptions {
            config: cfg,
            milestone: DisaggMilestone::PdCaching3,
            real_sleep: false,
        },
        runtime,
    )?;
    let sampling = SamplingParams {
        max_new_tokens: 6,
        eos_token: u32::MAX,
        ..Default::default()
    };

    println!("phase 1: all 3 instances healthy");
    for i in 0..6u32 {
        let rid = cluster.submit(toks(40, i), i as u64, sampling)?;
        let (g, rec) = cluster.collect(rid, Duration::from_secs(60))?;
        println!(
            "  rid={rid} served by inst{} gen={} jct={:.3}s",
            rec.decode_instance,
            g.len(),
            rec.jct()
        );
    }

    let victim = cluster.instances()[1].0;
    println!("\nphase 2: killing {victim} (heartbeats stop)");
    cluster.kill(victim);
    // Wait past heartbeat_ms * misses for detection.
    std::thread::sleep(Duration::from_millis(400));
    println!(
        "  cluster manager says alive({victim}) = {}",
        cluster.is_alive(victim)
    );
    assert!(!cluster.is_alive(victim), "failure not detected");

    println!("\nphase 3: traffic continues on survivors");
    for i in 10..16u32 {
        let rid = cluster.submit(toks(40, i), i as u64, sampling)?;
        let (g, rec) = cluster.collect(rid, Duration::from_secs(60))?;
        assert_ne!(rec.decode_instance, victim.0, "routed to dead instance");
        println!(
            "  rid={rid} served by inst{} gen={} jct={:.3}s",
            rec.decode_instance,
            g.len(),
            rec.jct()
        );
    }
    println!("\nfailover OK: detection + membership broadcast + re-routing");
    cluster.shutdown();
    Ok(())
}
