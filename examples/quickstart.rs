//! Quickstart: load the AOT model, start a PD-colocated instance with
//! context caching, and serve a few text prompts end-to-end.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Demonstrates the full stack: GS tokenization → prompt-tree routing →
//! MemPool cache match → Pallas-kernel prefill via PJRT → device-resident
//! decode → KV retirement into the radix index. The second, longer prompt
//! shares a prefix with the first and hits the cache.

use std::sync::Arc;
use std::time::Duration;

use memserve::config::Config;
use memserve::engine::{DisaggMilestone, SamplingParams};
use memserve::runtime::ModelRuntime;
use memserve::server::{ServeCluster, ServeOptions};

fn main() -> anyhow::Result<()> {
    memserve::util::logging::init();
    let mut cfg = Config::default();
    cfg.cluster.prefill_instances = 0;
    cfg.cluster.decode_instances = 0;
    cfg.cluster.colocated_instances = 1;

    println!("loading + compiling AOT artifacts (once per process)...");
    let runtime = Arc::new(ModelRuntime::load(&cfg.artifacts_dir)?);
    println!(
        "model: {} layers, d_model {}, {:.1}M params, vocab {}",
        runtime.meta.layers,
        runtime.meta.d_model,
        runtime.meta.param_count as f64 / 1e6,
        runtime.meta.vocab
    );
    let cluster = ServeCluster::start(
        ServeOptions {
            config: cfg,
            milestone: DisaggMilestone::PdCaching3,
            real_sleep: false,
        },
        runtime,
    )?;

    let system = "you are a helpful assistant. answer briefly and cite \
                  sources when you can. the user is a systems researcher \
                  reproducing the memserve paper on a tiny transformer.";
    let prompts = [
        format!("{system} user: what is a kv cache?"),
        format!("{system} user: what is a kv cache? and why does prefix \
                 caching cut the time to first token so much?"),
        format!("{system} user: explain disaggregated inference."),
    ];
    let sampling = SamplingParams {
        max_new_tokens: 24,
        eos_token: u32::MAX,
        ..Default::default()
    };
    for (i, p) in prompts.iter().enumerate() {
        let rid = cluster.submit_text(p, 1, sampling)?;
        let (tokens, rec) = cluster.collect(rid, Duration::from_secs(60))?;
        println!(
            "[{}] prompt_tokens={} cached={} ({:.0}%) generated={:?}... \
             ttft={:.3}s jct={:.3}s tpot={:.4}s",
            i,
            rec.prompt_tokens,
            rec.cached_tokens,
            100.0 * rec.cached_ratio(),
            &tokens[..4.min(tokens.len())],
            rec.ttft(),
            rec.jct(),
            rec.tpot(),
        );
    }
    let m = cluster.metrics();
    println!("\n== metrics ==\n{}", m.summary_line());
    cluster.shutdown();
    Ok(())
}
