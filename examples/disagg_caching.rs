//! The end-to-end validation driver (EXPERIMENTS.md §E2E): serve a real
//! multi-turn workload through all four paper settings on live PJRT
//! compute and report TTFT / JCT / TPOT + cache and wire statistics.
//!
//!     make artifacts && cargo run --release --example disagg_caching
//!
//! Settings (paper §8.3): PD (colocated, vanilla), PD-CC (colocated +
//! caching), 1P1D (disaggregated, PD-Basic), 1P1D-CC (disaggregated +
//! full PD-Caching-3). All four run the same ShareGPT-like session
//! schedule with causal turn dependencies; greedy decoding makes outputs
//! comparable across settings (and they must be identical).

use std::sync::Arc;
use std::time::Duration;

use memserve::config::Config;
use memserve::engine::{DisaggMilestone, SamplingParams};
use memserve::metrics::Metrics;
use memserve::runtime::ModelRuntime;
use memserve::server::{ClientHandle, ServeCluster, ServeOptions};
use memserve::util::bench::Table;
use memserve::workload::{WorkloadKind, WorkloadSpec};

struct Setting {
    name: &'static str,
    prefill: usize,
    decode: usize,
    colocated: usize,
    caching: bool,
    milestone: DisaggMilestone,
}

const SETTINGS: [Setting; 4] = [
    Setting {
        name: "PD",
        prefill: 0,
        decode: 0,
        colocated: 2,
        caching: false,
        milestone: DisaggMilestone::PdBasic,
    },
    Setting {
        name: "PD-CC",
        prefill: 0,
        decode: 0,
        colocated: 2,
        caching: true,
        milestone: DisaggMilestone::PdCaching3,
    },
    Setting {
        name: "1P1D",
        prefill: 1,
        decode: 1,
        colocated: 0,
        caching: false,
        milestone: DisaggMilestone::PdBasic,
    },
    Setting {
        name: "1P1D-CC",
        prefill: 1,
        decode: 1,
        colocated: 0,
        caching: true,
        milestone: DisaggMilestone::PdCaching3,
    },
];

fn run_setting(
    s: &Setting,
    runtime: Arc<ModelRuntime>,
    spec: &WorkloadSpec,
    turns_cap: usize,
) -> anyhow::Result<(Metrics, u64, Vec<Vec<u32>>)> {
    let mut cfg = Config::default();
    cfg.cluster.prefill_instances = s.prefill;
    cfg.cluster.decode_instances = s.decode;
    cfg.cluster.colocated_instances = s.colocated;
    cfg.mempool.context_caching = s.caching;
    let cluster: ClientHandle = ServeCluster::start(
        ServeOptions {
            config: cfg,
            milestone: s.milestone,
            real_sleep: false,
        },
        runtime,
    )?;
    let max_seq = 512;
    // Drive sessions concurrently: submit every session's next turn as
    // soon as its previous response lands (causal dependency), up to
    // `turns_cap` turns per session.
    let mut outputs = vec![];
    let mut ctxs: Vec<Vec<u32>> = spec
        .sessions
        .iter()
        .map(|s| s.shared_prefix.clone())
        .collect();
    for turn in 0..turns_cap {
        let mut batch = vec![];
        for (si, sess) in spec.sessions.iter().enumerate() {
            let Some(t) = sess.turns.get(turn) else { continue };
            let mut prompt = ctxs[si].clone();
            prompt.extend_from_slice(&t.user_tokens);
            let gen = t.target_gen.min(24).max(2);
            if prompt.len() + gen + 1 >= max_seq {
                continue;
            }
            let rid = cluster.submit(prompt.clone(), sess.id, SamplingParams {
                max_new_tokens: gen,
                eos_token: u32::MAX,
                ..Default::default()
            })?;
            batch.push((si, rid, prompt));
        }
        for (si, rid, prompt) in batch {
            let (generated, _) =
                cluster.collect(rid, Duration::from_secs(300))?;
            outputs.push(generated.clone());
            ctxs[si] = prompt;
            ctxs[si].extend(generated);
        }
    }
    let metrics = cluster.metrics();
    let wire = cluster.net_stats().payload_bytes;
    cluster.shutdown();
    Ok((metrics, wire, outputs))
}

fn main() -> anyhow::Result<()> {
    memserve::util::logging::init();
    let t_start = std::time::Instant::now();
    println!("loading + compiling AOT artifacts...");
    let runtime = Arc::new(ModelRuntime::load("artifacts")?);
    let spec = WorkloadSpec::generate(
        WorkloadKind::ShareGpt,
        6,   // sessions
        42,  // seed
        runtime.meta.vocab as u32,
        runtime.meta.max_seq,
    );
    let turns_cap = 3;
    println!(
        "workload: {} sessions x up to {turns_cap} turns (ShareGPT-like)",
        spec.sessions.len()
    );

    let mut table = Table::new("e2e_disagg_caching", &[
        "setting", "requests", "cached_ratio", "ttft_mean_s", "ttft_p99_s",
        "jct_mean_s", "jct_p99_s", "tpot_mean_s", "wire_MB",
    ]);
    let mut all_outputs: Vec<(&str, Vec<Vec<u32>>)> = vec![];
    for s in &SETTINGS {
        println!("== running {} ==", s.name);
        let (m, wire, outs) =
            run_setting(s, runtime.clone(), &spec, turns_cap)?;
        let jct = m.jct();
        let ttft = m.ttft();
        let tpot = m.tpot();
        table.row(vec![
            s.name.into(),
            m.records.len().to_string(),
            format!("{:.3}", m.mean_cached_ratio()),
            format!("{:.4}", ttft.mean),
            format!("{:.4}", ttft.p99),
            format!("{:.4}", jct.mean),
            format!("{:.4}", jct.p99),
            format!("{:.5}", tpot.mean),
            format!("{:.2}", wire as f64 / 1e6),
        ]);
        all_outputs.push((s.name, outs));
    }
    table.finish();

    // Cross-setting correctness: greedy outputs identical in every
    // setting (caching and disaggregation are performance features, not
    // semantic ones).
    let reference = &all_outputs[0].1;
    for (name, outs) in &all_outputs[1..] {
        assert_eq!(
            outs, reference,
            "{name} changed generated tokens vs PD baseline"
        );
    }
    println!(
        "\nAll settings produced IDENTICAL generations \
         ({} responses) — caching/disaggregation are output-transparent.",
        reference.len()
    );
    println!("total wall time: {:.1}s", t_start.elapsed().as_secs_f64());
    Ok(())
}
