#!/usr/bin/env bash
# Emit a Cargo.toml for the rust/ crate. The repo ships sources only —
# the serving harness normally synthesizes the manifest — so CI (and any
# local checkout) can bootstrap one with this script:
#
#   cd rust && ../.github/gen-cargo-toml.sh > Cargo.toml
#
# Benches and examples live at the repo root and are declared
# explicitly; every bench has its own main() (harness = false).
#
# `--loom` additionally declares the loom model-checker as a
# `cfg(loom)`-only dependency (ISSUE 10). It is compiled solely when
# RUSTFLAGS="--cfg loom" — the normal build graph is unchanged, which
# is why the loom CI job regenerates the manifest with this flag while
# every other job uses the bare form.
set -euo pipefail

WITH_LOOM=0
for arg in "$@"; do
  case "$arg" in
    --loom) WITH_LOOM=1 ;;
    *) echo "error: unknown flag '$arg' (supported: --loom)" >&2; exit 1 ;;
  esac
done

if [ ! -f src/lib.rs ] || [ ! -d ../benches ]; then
  echo "error: run from the rust/ crate directory (src/lib.rs and ../benches must exist)" >&2
  exit 1
fi

cat <<'EOF'
[package]
name = "memserve"
version = "0.1.0"
edition = "2021"

[dependencies]
anyhow = "1"
thiserror = "1"
once_cell = "1"
xla = "0.1"

[[bin]]
name = "memserve"
path = "src/main.rs"

# `--cfg loom` is an expected custom cfg (the util::sync shim), not a
# typo'd feature — tell check-cfg so `-D warnings` builds stay clean.
[lints.rust]
unexpected_cfgs = { level = "warn", check-cfg = ["cfg(loom)"] }
EOF

if [ "$WITH_LOOM" = 1 ]; then
  cat <<'LOOMEOF'

[target.'cfg(loom)'.dependencies]
loom = "0.7"
LOOMEOF
fi

for b in ../benches/*.rs; do
  name=$(basename "$b" .rs)
  cat <<EOF

[[bench]]
name = "$name"
path = "$b"
harness = false
EOF
done

for e in ../examples/*.rs; do
  name=$(basename "$e" .rs)
  cat <<EOF

[[example]]
name = "$name"
path = "$e"
EOF
done
