#!/usr/bin/env bash
# Emit a Cargo.toml for the rust/ crate. The repo ships sources only —
# the serving harness normally synthesizes the manifest — so CI (and any
# local checkout) can bootstrap one with this script:
#
#   cd rust && ../.github/gen-cargo-toml.sh > Cargo.toml
#
# Benches and examples live at the repo root and are declared
# explicitly; every bench has its own main() (harness = false).
set -euo pipefail

if [ ! -f src/lib.rs ] || [ ! -d ../benches ]; then
  echo "error: run from the rust/ crate directory (src/lib.rs and ../benches must exist)" >&2
  exit 1
fi

cat <<'EOF'
[package]
name = "memserve"
version = "0.1.0"
edition = "2021"

[dependencies]
anyhow = "1"
thiserror = "1"
once_cell = "1"
xla = "0.1"

[[bin]]
name = "memserve"
path = "src/main.rs"
EOF

for b in ../benches/*.rs; do
  name=$(basename "$b" .rs)
  cat <<EOF

[[bench]]
name = "$name"
path = "$b"
harness = false
EOF
done

for e in ../examples/*.rs; do
  name=$(basename "$e" .rs)
  cat <<EOF

[[example]]
name = "$name"
path = "$e"
EOF
done
