"""L1 correctness: Pallas prefix-attention kernel vs the pure-jnp oracle.

This is the core numeric signal for the whole stack: the Rust engine's
prefill path executes HLO lowered from this kernel, so any mismatch here
propagates to the serving layer.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.prefix_attention import prefix_attention
from compile.kernels.ref import ref_prefix_attention, ref_full_causal

TOL = 2e-5


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


def run_both(rng, heads, n, c, hd, cache_len, new_len, **kw):
    q = rand(rng, heads, n, hd)
    kc = rand(rng, heads, c, hd)
    vc = rand(rng, heads, c, hd)
    kn = rand(rng, heads, n, hd)
    vn = rand(rng, heads, n, hd)
    cl = jnp.array([cache_len], jnp.int32)
    nl = jnp.array([new_len], jnp.int32)
    out = prefix_attention(q, kc, vc, kn, vn, cl, nl, **kw)
    ref = ref_prefix_attention(q, kc, vc, kn, vn, cl, nl)
    return np.asarray(out), np.asarray(ref)


# ---------------------------------------------------------------------------
# Deterministic cases
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,c,cache_len,new_len", [
    (16, 0, 0, 16),
    (16, 256, 0, 16),       # cache buffer present but empty
    (16, 256, 256, 16),     # full cache
    (64, 256, 100, 64),
    (64, 256, 100, 1),      # mostly padding
    (128, 512, 37, 128),
    (256, 512, 512, 256),   # max everything
    (256, 512, 1, 3),
    (32, 256, 255, 32),     # cache_len not chunk-aligned
])
def test_kernel_matches_ref(n, c, cache_len, new_len):
    rng = np.random.default_rng(n * 1000 + c + cache_len)
    out, ref = run_both(rng, 8, n, c, 32, cache_len, new_len)
    np.testing.assert_allclose(out, ref, atol=TOL, rtol=TOL)


@pytest.mark.parametrize("block_q,block_k", [
    (16, 32), (32, 64), (64, 128), (64, 64), (16, 256)])
def test_kernel_tile_shapes(block_q, block_k):
    """The result must be tile-shape independent (pure schedule change)."""
    rng = np.random.default_rng(7)
    out, ref = run_both(rng, 4, 64, 256, 32, 130, 64,
                        block_q=block_q, block_k=block_k)
    np.testing.assert_allclose(out, ref, atol=TOL, rtol=TOL)


def test_kernel_no_cache_variant_equals_causal():
    rng = np.random.default_rng(9)
    h, n, hd = 8, 64, 32
    q = rand(rng, h, n, hd)
    kn = rand(rng, h, n, hd)
    vn = rand(rng, h, n, hd)
    z = jnp.zeros((h, 0, hd), jnp.float32)
    out = prefix_attention(q, z, z, kn, vn,
                           jnp.array([0], jnp.int32),
                           jnp.array([n], jnp.int32))
    ref = ref_full_causal(q, kn, vn)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=TOL, rtol=TOL)


def test_kernel_first_token_attends_only_to_cache_and_self():
    """Row 0 with cache_len=c must equal softmax over exactly c+1 keys."""
    rng = np.random.default_rng(11)
    h, n, c, hd = 2, 16, 256, 32
    out, ref = run_both(rng, h, n, c, hd, 19, 16)
    np.testing.assert_allclose(out[:, 0], ref[:, 0], atol=TOL, rtol=TOL)


def test_kernel_is_deterministic():
    rng = np.random.default_rng(13)
    h, n, c, hd = 4, 32, 256, 32
    q = rand(rng, h, n, hd)
    kc = rand(rng, h, c, hd)
    vc = rand(rng, h, c, hd)
    kn = rand(rng, h, n, hd)
    vn = rand(rng, h, n, hd)
    cl = jnp.array([77], jnp.int32)
    nl = jnp.array([32], jnp.int32)
    a = np.asarray(prefix_attention(q, kc, vc, kn, vn, cl, nl))
    b = np.asarray(prefix_attention(q, kc, vc, kn, vn, cl, nl))
    np.testing.assert_array_equal(a, b)


def test_kernel_padding_rows_do_not_affect_real_rows():
    """Changing garbage q rows >= new_len must not change rows < new_len."""
    rng = np.random.default_rng(17)
    h, n, c, hd = 4, 64, 256, 32
    q = rand(rng, h, n, hd)
    kc = rand(rng, h, c, hd)
    vc = rand(rng, h, c, hd)
    kn = rand(rng, h, n, hd)
    vn = rand(rng, h, n, hd)
    cl = jnp.array([50], jnp.int32)
    new_len = 20
    nl = jnp.array([new_len], jnp.int32)
    out1 = np.asarray(prefix_attention(q, kc, vc, kn, vn, cl, nl))
    q2 = q.at[:, new_len:].set(123.0)
    # padded *keys* also change: rows < new_len must be unaffected because
    # the mask excludes cols >= new_len
    kn2 = kn.at[:, new_len:].set(-55.0)
    vn2 = vn.at[:, new_len:].set(99.0)
    out2 = np.asarray(prefix_attention(q2, kc, vc, kn2, vn2, cl, nl))
    np.testing.assert_allclose(out1[:, :new_len], out2[:, :new_len],
                               atol=TOL, rtol=TOL)


# ---------------------------------------------------------------------------
# Hypothesis sweep: shapes, cache ratios, tile sizes
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None, derandomize=True)
@given(
    heads=st.sampled_from([1, 2, 4, 8]),
    n=st.sampled_from([16, 32, 64, 128]),
    c=st.sampled_from([0, 64, 128, 256, 512]),
    hd=st.sampled_from([8, 16, 32, 64]),
    ratio=st.floats(0.0, 1.0),
    newfrac=st.floats(0.05, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_hypothesis_sweep(heads, n, c, hd, ratio, newfrac, seed):
    cache_len = int(round(c * ratio))
    new_len = max(1, int(round(n * newfrac)))
    rng = np.random.default_rng(seed)
    out, ref = run_both(rng, heads, n, c, hd, cache_len, new_len)
    real = out[:, :new_len]
    np.testing.assert_allclose(real, ref[:, :new_len], atol=3e-5, rtol=3e-5)
    assert np.all(np.isfinite(out)), "non-finite attention output"


@settings(max_examples=15, deadline=None, derandomize=True)
@given(
    scale=st.sampled_from([1e-3, 1.0, 30.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_numeric_stability_extreme_logits(scale, seed):
    """Online softmax must survive large-magnitude scores (no inf/nan)."""
    rng = np.random.default_rng(seed)
    h, n, c, hd = 2, 32, 128, 16
    q = rand(rng, h, n, hd) * scale
    kc = rand(rng, h, c, hd) * scale
    vc = rand(rng, h, c, hd)
    kn = rand(rng, h, n, hd) * scale
    vn = rand(rng, h, n, hd)
    cl = jnp.array([c], jnp.int32)
    nl = jnp.array([n], jnp.int32)
    out = np.asarray(prefix_attention(q, kc, vc, kn, vn, cl, nl))
    ref = np.asarray(ref_prefix_attention(q, kc, vc, kn, vn, cl, nl))
    assert np.all(np.isfinite(out))
    np.testing.assert_allclose(out, ref, atol=2e-4, rtol=1e-2)
