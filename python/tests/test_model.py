"""L2 correctness: the serving invariants the Rust engine relies on.

The whole MemServe design rests on three equivalences:
  1. *Context caching is exact*: prefill(suffix | cached prefix KV) must
     produce the same logits as prefill(full prompt).
  2. *Decode continues prefill*: one decode step at position p equals the
     last-token logits of a prefill of p+1 tokens.
  3. *KV is relocatable*: KV produced in one buffer capacity is valid in
     any other (blocks can be gathered/scattered/transferred) — paper
     §4.2's "no reshaping" claim.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.geometry import TINY, BUCKETS
from compile.params import init_params, param_order
from compile.model import prefill, decode, unpack_params

GEOM = TINY
PARAMS = [jnp.asarray(p) for p in init_params(GEOM)]
TOL = 5e-4


def rand_tokens(rng, n):
    return jnp.asarray(rng.integers(0, GEOM.vocab, n), jnp.int32)


def kv_buffer(c):
    return jnp.zeros((GEOM.layers, 2, c, GEOM.n_heads, GEOM.head_dim),
                     jnp.float32)


def pad_tokens(toks, n):
    assert len(toks) <= n
    return jnp.pad(toks, (0, n - len(toks)))


def full_prefill(toks, bucket=None):
    n = bucket or len(toks)
    return prefill(GEOM, PARAMS, pad_tokens(toks, n),
                   jnp.int32(len(toks)), jnp.int32(0))


class TestParamPlumbing:
    def test_param_order_matches_init(self):
        order = param_order(GEOM)
        assert len(order) == len(PARAMS)
        for (name, shape), arr in zip(order, PARAMS):
            assert tuple(arr.shape) == tuple(shape), name

    def test_unpack_consumes_everything(self):
        p = unpack_params(GEOM, PARAMS)
        assert len(p["layers"]) == GEOM.layers

    def test_param_count_formula(self):
        total = sum(int(np.prod(a.shape)) for a in PARAMS)
        assert total == GEOM.param_count()


class TestPrefill:
    def test_bucket_padding_invariance(self):
        """Same prompt in different N buckets -> same logits and KV."""
        rng = np.random.default_rng(0)
        toks = rand_tokens(rng, 30)
        kv64, logits64 = full_prefill(toks, 64)
        kv32, logits32 = full_prefill(toks, 32)
        np.testing.assert_allclose(logits64, logits32, atol=TOL, rtol=TOL)
        np.testing.assert_allclose(kv64[:, :, :30], kv32[:, :, :30],
                                   atol=TOL, rtol=TOL)

    def test_cached_prefill_exactness(self):
        """Invariant 1: caching changes nothing numerically."""
        rng = np.random.default_rng(1)
        toks = rand_tokens(rng, 120)
        kv_full, logits_full = full_prefill(toks, 128)
        for split in (16, 64, 100):
            kv_a, _ = full_prefill(toks[:split], 128)
            buf = kv_buffer(256).at[:, :, :split].set(kv_a[:, :, :split])
            rest = toks[split:]
            n_bucket = 32 if len(rest) <= 32 else 128
            _, logits_b = prefill(
                GEOM, PARAMS, pad_tokens(rest, n_bucket),
                jnp.int32(len(rest)), jnp.int32(split), buf)
            np.testing.assert_allclose(logits_b, logits_full,
                                       atol=TOL, rtol=TOL)

    def test_cache_capacity_invariance(self):
        """Invariant 3: C=256 vs C=512 buckets agree given same prefix."""
        rng = np.random.default_rng(2)
        toks = rand_tokens(rng, 80)
        kv_a, _ = full_prefill(toks[:48], 64)
        rest = pad_tokens(toks[48:], 32)
        out = []
        for cap in (256, 512):
            buf = kv_buffer(cap).at[:, :, :48].set(kv_a[:, :, :48])
            _, logits = prefill(GEOM, PARAMS, rest, jnp.int32(32),
                                jnp.int32(48), buf)
            out.append(np.asarray(logits))
        np.testing.assert_allclose(out[0], out[1], atol=TOL, rtol=TOL)

    def test_garbage_beyond_cache_len_ignored(self):
        rng = np.random.default_rng(3)
        toks = rand_tokens(rng, 40)
        kv_a, _ = full_prefill(toks[:24], 32)
        buf = kv_buffer(256).at[:, :, :24].set(kv_a[:, :, :24])
        buf_dirty = buf.at[:, :, 24:].set(777.0)
        rest = pad_tokens(toks[24:], 16)
        _, l1 = prefill(GEOM, PARAMS, rest, jnp.int32(16), jnp.int32(24), buf)
        _, l2 = prefill(GEOM, PARAMS, rest, jnp.int32(16), jnp.int32(24),
                        buf_dirty)
        np.testing.assert_allclose(l1, l2, atol=TOL, rtol=TOL)

    def test_logits_finite_and_discriminative(self):
        rng = np.random.default_rng(4)
        toks = rand_tokens(rng, 64)
        _, logits = full_prefill(toks, 64)
        logits = np.asarray(logits)
        assert np.all(np.isfinite(logits))
        assert logits.std() > 0.1, "degenerate logits"


class TestDecode:
    def test_decode_continues_prefill(self):
        """Invariant 2, chained over several steps."""
        rng = np.random.default_rng(5)
        toks = rand_tokens(rng, 40)
        kv_p, _ = full_prefill(toks[:32], 32)
        buf = kv_buffer(64).at[:, :, :32].set(kv_p[:, :, :32])
        for i in range(32, 40):
            logits_d, buf = decode(GEOM, PARAMS, toks[i:i + 1],
                                   jnp.int32(i), buf)
            _, logits_f = full_prefill(toks[:i + 1], 64)
            np.testing.assert_allclose(logits_d, logits_f,
                                       atol=TOL, rtol=TOL)

    def test_decode_ctx_bucket_invariance(self):
        rng = np.random.default_rng(6)
        toks = rand_tokens(rng, 20)
        kv_p, _ = full_prefill(toks[:16], 16)
        outs = []
        for ctx in (64, 128, 256):
            buf = kv_buffer(ctx).at[:, :, :16].set(kv_p[:, :, :16])
            logits, _ = decode(GEOM, PARAMS, toks[16:17], jnp.int32(16), buf)
            outs.append(np.asarray(logits))
        np.testing.assert_allclose(outs[0], outs[1], atol=TOL, rtol=TOL)
        np.testing.assert_allclose(outs[0], outs[2], atol=TOL, rtol=TOL)

    def test_decode_writes_kv_in_place(self):
        rng = np.random.default_rng(7)
        toks = rand_tokens(rng, 17)
        kv_p, _ = full_prefill(toks, 32)
        buf = kv_buffer(64).at[:, :, :17].set(kv_p[:, :, :17])
        tok = rand_tokens(rng, 1)
        _, buf2 = decode(GEOM, PARAMS, tok, jnp.int32(17), buf)
        # untouched region identical
        np.testing.assert_array_equal(np.asarray(buf2[:, :, :17]),
                                      np.asarray(buf[:, :, :17]))
        # written slot differs from zero
        assert np.abs(np.asarray(buf2[:, :, 17])).max() > 0


class TestHypothesisModel:
    @settings(max_examples=10, deadline=None, derandomize=True)
    @given(split_frac=st.floats(0.1, 0.9), seed=st.integers(0, 2**31 - 1))
    def test_cached_prefill_random_splits(self, split_frac, seed):
        rng = np.random.default_rng(seed)
        total = int(rng.integers(20, 120))
        split = max(1, min(total - 1, int(total * split_frac)))
        toks = rand_tokens(rng, total)
        bucket = 128
        _, logits_full = full_prefill(toks, bucket)
        kv_a, _ = full_prefill(toks[:split], bucket)
        buf = kv_buffer(256).at[:, :, :split].set(kv_a[:, :, :split])
        _, logits_b = prefill(GEOM, PARAMS, pad_tokens(toks[split:], bucket),
                              jnp.int32(total - split), jnp.int32(split), buf)
        np.testing.assert_allclose(logits_b, logits_full, atol=1e-3,
                                   rtol=1e-3)


class TestBuckets:
    def test_variants_cover_max_seq(self):
        variants = BUCKETS.prefill_variants(GEOM.max_seq)
        assert (256, 512) in variants
        assert (16, 0) in variants
        # any (cache_len, new_len) with sum <= max_seq has a bucket
        for cache_len in (0, 1, 255, 256, 400, 496):
            for new_len in (1, 16, 100):
                if cache_len + new_len > GEOM.max_seq:
                    continue
                n_ok = [n for n, c in variants
                        if n >= new_len and (c >= cache_len or
                                             (c == 0 and cache_len == 0))]
                assert n_ok, (cache_len, new_len)
