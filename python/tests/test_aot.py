"""AOT artifact integrity: meta.json + weights.bin + HLO text contracts.

The Rust runtime consumes these files blind; this suite is the build-time
gate that the cross-language ABI (argument order, shapes, weight layout)
is intact.
"""

import json
import hashlib
import os
import struct

import numpy as np
import pytest

from compile.geometry import TINY, BUCKETS
from compile.params import init_params, param_order
from compile.aot import lower_prefill, lower_decode, to_hlo_text

ART = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "..",
                                   "artifacts"))

needs_artifacts = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "meta.json")),
    reason="run `make artifacts` first")


@pytest.fixture(scope="module")
def meta():
    with open(os.path.join(ART, "meta.json")) as f:
        return json.load(f)


@needs_artifacts
class TestMeta:
    def test_model_geometry_matches(self, meta):
        m = meta["model"]
        assert m["vocab"] == TINY.vocab
        assert m["layers"] == TINY.layers
        assert m["d_model"] == TINY.d_model
        assert m["n_heads"] == TINY.n_heads
        assert m["head_dim"] == TINY.head_dim
        assert m["param_count"] == TINY.param_count()

    def test_every_artifact_file_exists(self, meta):
        for name, fname in meta["artifacts"].items():
            path = os.path.join(ART, fname)
            assert os.path.exists(path), name
            assert os.path.getsize(path) > 1000, name

    def test_buckets_match_geometry(self, meta):
        expect = [[n, c] for n, c in BUCKETS.prefill_variants(TINY.max_seq)]
        assert meta["buckets"]["prefill"] == expect
        for n, c in expect:
            assert f"prefill_n{n}_c{c}" in meta["artifacts"]
        for ctx in meta["buckets"]["decode_ctx"]:
            assert f"decode_ctx{ctx}" in meta["artifacts"]

    def test_param_manifest_is_contiguous_and_ordered(self, meta):
        offset = 0
        order = param_order(TINY)
        assert len(meta["params"]) == len(order)
        for entry, (name, shape) in zip(meta["params"], order):
            assert entry["name"] == name
            assert entry["shape"] == list(shape)
            assert entry["offset_f32"] == offset
            assert entry["len_f32"] == int(np.prod(shape))
            offset += entry["len_f32"]
        assert offset == TINY.param_count()

    def test_weights_blob_matches_manifest_and_hash(self, meta):
        path = os.path.join(ART, meta["weights_file"])
        blob = open(path, "rb").read()
        assert len(blob) == 4 * TINY.param_count()
        assert hashlib.sha256(blob).hexdigest() == meta["weights_sha256"]

    def test_weights_reproduce_init(self, meta):
        path = os.path.join(ART, meta["weights_file"])
        blob = np.fromfile(path, dtype="<f4")
        params = init_params(TINY)
        for entry, arr in zip(meta["params"], params):
            start = entry["offset_f32"]
            seg = blob[start:start + entry["len_f32"]]
            np.testing.assert_array_equal(seg, arr.ravel(), err_msg=entry["name"])


@needs_artifacts
class TestHloText:
    def test_hlo_parses_as_module(self, meta):
        """Every artifact must start with an HloModule header (what
        HloModuleProto::from_text_file parses) and contain no custom-calls
        (the CPU PJRT client cannot run Mosaic/NEFF)."""
        for name, fname in meta["artifacts"].items():
            text = open(os.path.join(ART, fname)).read()
            assert text.startswith("HloModule"), name
            assert "custom-call" not in text.lower(), (
                f"{name} contains a custom-call — was the Pallas kernel "
                "lowered without interpret=True?")

    def test_prefill_entry_has_expected_arity(self, meta):
        n_params = len(meta["params"])
        text = open(os.path.join(ART,
                                 meta["artifacts"]["prefill_n16_c256"])).read()
        entry = [l for l in text.splitlines() if "ENTRY" in l][0]
        n_args = entry.count("parameter(") or entry.count(": ")
        # params + tokens + new_len + cache_len + kv_cache
        assert f"f32[{TINY.vocab},{TINY.d_model}]" in text  # embed param

    def test_decode_state_is_flat_and_untupled(self, meta):
        text = open(os.path.join(ART,
                                 meta["artifacts"]["decode_ctx64"])).read()
        state_len = TINY.vocab + TINY.layers * 2 * 64 * TINY.n_heads \
            * TINY.head_dim
        assert f"f32[{state_len}]" in text
        # Root must NOT be a tuple: the engine feeds the output buffer
        # back as the next step's state input. In this HLO text dialect
        # the signature lives on the entry computation's ROOT line.
        roots = [l for l in text.splitlines() if "ROOT" in l]
        entry_root = roots[-1]
        assert f"f32[{state_len}]" in entry_root, entry_root
        assert "tuple(" not in entry_root, entry_root


class TestLoweringRoundTrip:
    """Fresh lowering (independent of artifacts on disk)."""

    def test_lower_prefill_smallest(self):
        text = to_hlo_text(lower_prefill(TINY, 16, 0))
        assert text.startswith("HloModule")
        assert "custom-call" not in text.lower()

    def test_lower_decode_smallest(self):
        text = to_hlo_text(lower_decode(TINY, 64))
        assert text.startswith("HloModule")
