"""Pure-jnp oracles for the Pallas kernel and the attention semantics.

These are the correctness references: slow, obvious, mask-based attention
with no tiling and no online softmax. ``python/tests/test_kernel.py``
asserts the Pallas kernel matches ``ref_prefix_attention`` across a
hypothesis-driven sweep of shapes and cache ratios.
"""

import math

import jax.numpy as jnp
import numpy as np


def ref_prefix_attention(q, k_cache, v_cache, k_new, v_new, cache_len,
                         new_len):
    """Mask-based reference for kernels.prefix_attention.

    Same signature/semantics: q/k_new/v_new f32[H,N,hd], cache f32[H,C,hd],
    cache_len/new_len i32[1]. Rows >= new_len are unspecified; this oracle
    computes them under the same mask so they compare equal.
    """
    heads, n_new, hd = q.shape
    cache_cap = k_cache.shape[1]
    cl = jnp.asarray(cache_len).reshape(())
    nl = jnp.asarray(new_len).reshape(())

    k_all = jnp.concatenate([k_cache, k_new], axis=1)  # [H, C+N, hd]
    v_all = jnp.concatenate([v_cache, v_new], axis=1)
    scale = 1.0 / math.sqrt(hd)
    s = jnp.einsum("hqd,hkd->hqk", q, k_all) * scale   # [H, N, C+N]

    col = jnp.arange(cache_cap + n_new)
    row = jnp.arange(n_new)
    cached_ok = (col[None, :] < cl) & (col[None, :] < cache_cap)
    new_col = col[None, :] - cache_cap                  # local new index
    new_ok = (col[None, :] >= cache_cap) \
        & (new_col <= row[:, None]) & (new_col < nl)
    mask = cached_ok | new_ok                           # [N, C+N]

    s = jnp.where(mask[None, :, :], s, -1e30)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("hqk,hkd->hqd", p, v_all)


def ref_full_causal(q, k, v):
    """Plain causal attention over a full sequence (no cache, no padding)."""
    n = q.shape[1]
    zeros = jnp.zeros((q.shape[0], 0, q.shape[2]), q.dtype)
    return ref_prefix_attention(
        q, zeros, zeros, k, v,
        jnp.array([0], jnp.int32), jnp.array([n], jnp.int32))


def np_softmax(x, axis=-1):
    e = np.exp(x - x.max(axis=axis, keepdims=True))
    return e / e.sum(axis=axis, keepdims=True)
