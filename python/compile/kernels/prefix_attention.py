"""L1 Pallas kernel: prefix-cached prefill attention (FlashAttention-2 style).

This is MemServe's compute hot-spot: prefilling ``N`` new tokens whose
attention spans a *cached* prefix of ``cache_len`` tokens (the historical
KV cache MemPool matched for this prompt) plus the causal window over the
new tokens themselves. The cached-ratio ``y = cache_len / prompt_len`` is
exactly the knob the paper's cost model ``exec(x, y)`` studies (Fig 13/14).

Hardware adaptation (paper is CUDA / H800, see DESIGN.md §1): instead of a
threadblock-per-tile WMMA schedule we express the HBM->VMEM schedule with
a Pallas grid over Q tiles; all heads are vectorized inside one kernel
instance so the interpret-mode grid stays small and the lowered HLO stays
compact. K/V are streamed through the online-softmax inner loop in
``block_k`` chunks exactly as FlashAttention-2 does.

VMEM budget per grid step (f32): Q tile H*bq*hd + cached KV 2*H*C*hd +
new KV 2*H*N*hd + acc H*bq*hd. At the tiny geometry (H=8, hd=32, C=512,
N=256, bq=64) that is ~1.6 MiB, far under the ~16 MiB VMEM of a TPU core;
at paper scale (H=40, hd=128) the same BlockSpec keeps chunks < 8 MiB.

interpret=True is mandatory: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret mode lowers to plain HLO that the Rust runtime
(xla crate, xla_extension 0.5.1) compiles and runs.
"""

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _online_update(carry, s, v_chunk):
    """FlashAttention-2 online-softmax accumulator update."""
    m0, l0, acc0 = carry
    m1 = jnp.maximum(m0, s.max(axis=-1))
    alpha = jnp.exp(m0 - m1)
    p = jnp.exp(s - m1[..., None])
    l1 = l0 * alpha + p.sum(axis=-1)
    acc1 = acc0 * alpha[..., None] + jnp.einsum(
        "hqk,hkd->hqd", p, v_chunk, preferred_element_type=jnp.float32)
    return m1, l1, acc1


def _attn_kernel(*refs, block_q: int, block_k: int, cache_cap: int,
                 n_new: int, scale: float):
    """One grid step: all heads, one Q tile of ``block_q`` new tokens."""
    if cache_cap > 0:
        cl_ref, nl_ref, q_ref, kc_ref, vc_ref, kn_ref, vn_ref, o_ref = refs
    else:
        cl_ref, nl_ref, q_ref, kn_ref, vn_ref, o_ref = refs

    qt = pl.program_id(0)
    cache_len = cl_ref[0]
    new_len = nl_ref[0]

    q = q_ref[...]            # [H, block_q, hd]
    heads, bq, hd = q.shape

    # Local (within the new tokens) row indices of this Q tile.
    row = qt * block_q + jax.lax.broadcasted_iota(jnp.int32, (bq,), 0)

    m = jnp.full((heads, bq), NEG_INF, dtype=jnp.float32)
    l = jnp.zeros((heads, bq), dtype=jnp.float32)
    acc = jnp.zeros((heads, bq, hd), dtype=jnp.float32)

    # --- Phase 1: stream the cached prefix KV in block_k chunks. ---------
    if cache_cap > 0:
        kc = kc_ref[...]      # [H, C, hd] (VMEM-resident for this step)
        vc = vc_ref[...]

        def cached_body(i, carry):
            start = i * block_k
            k_chunk = jax.lax.dynamic_slice_in_dim(kc, start, block_k, axis=1)
            v_chunk = jax.lax.dynamic_slice_in_dim(vc, start, block_k, axis=1)
            col = start + jax.lax.broadcasted_iota(jnp.int32, (block_k,), 0)
            s = jnp.einsum("hqd,hkd->hqk", q, k_chunk,
                           preferred_element_type=jnp.float32) * scale
            s = jnp.where(col[None, None, :] < cache_len, s, NEG_INF)
            return _online_update(carry, s, v_chunk)

        n_chunks = cache_cap // block_k
        m, l, acc = jax.lax.fori_loop(0, n_chunks, cached_body, (m, l, acc))

    # --- Phase 2: causal attention over the new tokens. ------------------
    kn = kn_ref[...]          # [H, N, hd]
    vn = vn_ref[...]
    bk_new = min(block_k, n_new)

    def new_body(i, carry):
        start = i * bk_new
        k_chunk = jax.lax.dynamic_slice_in_dim(kn, start, bk_new, axis=1)
        v_chunk = jax.lax.dynamic_slice_in_dim(vn, start, bk_new, axis=1)
        col = start + jax.lax.broadcasted_iota(jnp.int32, (bk_new,), 0)
        s = jnp.einsum("hqd,hkd->hqk", q, k_chunk,
                       preferred_element_type=jnp.float32) * scale
        # Causal within new tokens AND only real (non-padded) new tokens.
        mask = (col[None, :] <= row[:, None]) & (col[None, :] < new_len)
        s = jnp.where(mask[None, :, :], s, NEG_INF)
        return _online_update(carry, s, v_chunk)

    m, l, acc = jax.lax.fori_loop(0, n_new // bk_new, new_body, (m, l, acc))

    out = acc / jnp.maximum(l, 1e-30)[..., None]
    o_ref[...] = out.astype(o_ref.dtype)


def prefix_attention(q, k_cache, v_cache, k_new, v_new, cache_len, new_len,
                     *, block_q: int = 64, block_k: int = 128,
                     interpret: bool = True):
    """Attention of ``N`` new queries over cached prefix + causal new KV.

    Args:
      q:        f32[H, N, hd]  (RoPE already applied)
      k_cache:  f32[H, C, hd]  post-RoPE cached keys (C may be 0)
      v_cache:  f32[H, C, hd]
      k_new:    f32[H, N, hd]  post-RoPE new keys
      v_new:    f32[H, N, hd]
      cache_len: i32[1]  number of valid cached tokens (<= C)
      new_len:   i32[1]  number of real new tokens (<= N)

    Returns: f32[H, N, hd]. Rows >= new_len are padding garbage.
    """
    heads, n_new, hd = q.shape
    cache_cap = k_cache.shape[1]
    block_q = min(block_q, n_new)
    assert n_new % block_q == 0, (n_new, block_q)
    if cache_cap > 0:
        block_k = min(block_k, cache_cap)
        assert cache_cap % block_k == 0, (cache_cap, block_k)
    bk_new = min(block_k, n_new)
    assert n_new % bk_new == 0, (n_new, bk_new)

    scale = 1.0 / math.sqrt(hd)
    grid = (n_new // block_q,)

    kernel = functools.partial(
        _attn_kernel, block_q=block_q, block_k=block_k,
        cache_cap=cache_cap, n_new=n_new, scale=scale)

    scalar_spec = pl.BlockSpec((1,), lambda qt: (0,))
    q_spec = pl.BlockSpec((heads, block_q, hd), lambda qt: (0, qt, 0))
    new_kv_spec = pl.BlockSpec((heads, n_new, hd), lambda qt: (0, 0, 0))

    operands = [cache_len, new_len, q]
    in_specs = [scalar_spec, scalar_spec, q_spec]
    if cache_cap > 0:
        cache_spec = pl.BlockSpec((heads, cache_cap, hd), lambda qt: (0, 0, 0))
        operands += [k_cache, v_cache]
        in_specs += [cache_spec, cache_spec]
    operands += [k_new, v_new]
    in_specs += [new_kv_spec, new_kv_spec]

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct((heads, n_new, hd), q.dtype),
        interpret=interpret,
    )(*operands)
