"""AOT pass: lower every (bucket) variant of the L2 model to HLO text.

Run once at build time (``make artifacts``); the Rust runtime loads the
results and Python never appears on the request path.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(what the published ``xla`` 0.1.6 crate links) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs in --out-dir (default ../artifacts):
  meta.json                   model geometry, buckets, param manifest,
                              artifact index, argument-order contract
  weights.bin                 flat little-endian f32 parameter blob
  prefill_n{N}_c{C}.hlo.txt   one per prefill bucket
  decode_ctx{CTX}.hlo.txt     one per decode context bucket

Usage: python -m compile.aot [--out-dir DIR] [--big] [--skip-existing]
"""

import argparse
import hashlib
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .geometry import TINY, BUCKETS, BigGeometry, ModelGeometry
from . import model as M
from .params import init_params, param_order, write_weights


def to_hlo_text(lowered, return_tuple=True) -> str:
    """stablehlo -> XlaComputation -> HLO text.

    return_tuple=True for multi-output graphs (the Rust side unwraps with
    to_tupleN); False for the single-output decode_state graph so the PJRT
    output is a plain (feedback-able) buffer, not a tuple."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=return_tuple)
    return comp.as_hlo_text()


def lower_prefill(geom: ModelGeometry, n: int, c: int):
    n_params = len(param_order(geom))
    pspecs = [jax.ShapeDtypeStruct(s, jnp.float32)
              for _, s in param_order(geom)]
    tok = jax.ShapeDtypeStruct((n,), jnp.int32)
    scalar = jax.ShapeDtypeStruct((), jnp.int32)

    if c > 0:
        kv = jax.ShapeDtypeStruct(
            (geom.layers, 2, c, geom.n_heads, geom.head_dim), jnp.float32)

        def fn(*args):
            params = list(args[:n_params])
            tokens, new_len, cache_len, kv_cache = args[n_params:]
            return M.prefill(geom, params, tokens, new_len, cache_len,
                             kv_cache)

        return jax.jit(fn).lower(*pspecs, tok, scalar, scalar, kv)

    def fn(*args):
        params = list(args[:n_params])
        tokens, new_len, cache_len = args[n_params:]
        return M.prefill(geom, params, tokens, new_len, cache_len, None)

    return jax.jit(fn).lower(*pspecs, tok, scalar, scalar)


def lower_decode(geom: ModelGeometry, ctx: int):
    """Flat-state decode (single output; lowered with return_tuple=False)."""
    n_params = len(param_order(geom))
    pspecs = [jax.ShapeDtypeStruct(s, jnp.float32)
              for _, s in param_order(geom)]
    tok = jax.ShapeDtypeStruct((1,), jnp.int32)
    scalar = jax.ShapeDtypeStruct((), jnp.int32)
    state_len = geom.vocab + geom.layers * 2 * ctx * geom.n_heads \
        * geom.head_dim
    state = jax.ShapeDtypeStruct((state_len,), jnp.float32)

    def fn(*args):
        params = list(args[:n_params])
        token, pos, st = args[n_params:]
        return M.decode_state(geom, params, token, pos, st, ctx)

    return jax.jit(fn).lower(*pspecs, tok, scalar, state)


def emit(out_dir: str, geom: ModelGeometry, *, skip_existing: bool = False,
         quiet: bool = False):
    os.makedirs(out_dir, exist_ok=True)
    t0 = time.time()

    params = init_params(geom)
    weights_path = os.path.join(out_dir, "weights.bin")
    manifest = write_weights(geom, params, weights_path)

    artifacts = {}

    def emit_one(name, lower_fn, return_tuple=True):
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        artifacts[name] = os.path.basename(path)
        if skip_existing and os.path.exists(path):
            return
        text = to_hlo_text(lower_fn(), return_tuple=return_tuple)
        with open(path, "w") as f:
            f.write(text)
        if not quiet:
            print(f"  {name}: {len(text) / 1e6:.2f} MB "
                  f"({time.time() - t0:.1f}s)", flush=True)

    prefill_variants = BUCKETS.prefill_variants(geom.max_seq)
    for n, c in prefill_variants:
        emit_one(f"prefill_n{n}_c{c}",
                 lambda n=n, c=c: lower_prefill(geom, n, c))
    for ctx in BUCKETS.decode_ctx:
        if ctx <= geom.max_seq:
            emit_one(f"decode_ctx{ctx}",
                     lambda ctx=ctx: lower_decode(geom, ctx),
                     return_tuple=False)

    meta = {
        "format_version": 1,
        "model": {
            "vocab": geom.vocab,
            "layers": geom.layers,
            "d_model": geom.d_model,
            "n_heads": geom.n_heads,
            "head_dim": geom.head_dim,
            "ffn": geom.ffn,
            "max_seq": geom.max_seq,
            "rope_theta": geom.rope_theta,
            "norm_eps": geom.norm_eps,
            "param_count": geom.param_count(),
        },
        "buckets": {
            "prefill": [[n, c] for n, c in prefill_variants],
            "decode_ctx": [c for c in BUCKETS.decode_ctx
                           if c <= geom.max_seq],
        },
        # Argument-order contract: all weight tensors first (manifest
        # order), then the per-call arguments. Outputs are a tuple.
        "arg_order": {
            "prefill_cached": ["<params>", "tokens[i32,N]", "new_len[i32]",
                               "cache_len[i32]", "kv_cache[f32,L,2,C,H,hd]"],
            "prefill_nocache": ["<params>", "tokens[i32,N]", "new_len[i32]",
                                "cache_len[i32]"],
            "decode": ["<params>", "token[i32,1]", "pos[i32]",
                       "state[f32,V + L*2*CTX*H*hd]"],
        },
        "outputs": {
            "prefill": ["new_kv[f32,L,2,N,H,hd]", "logits[f32,V]"],
            # decode is single-output (non-tuple): state' = [logits | kv]
            "decode": ["state[f32,V + L*2*CTX*H*hd]"],
        },
        "params": manifest,
        "weights_file": "weights.bin",
        "weights_sha256": hashlib.sha256(
            open(weights_path, "rb").read()).hexdigest(),
        "artifacts": artifacts,
    }
    with open(os.path.join(out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    if not quiet:
        print(f"wrote {len(artifacts)} artifacts + weights "
              f"({geom.param_count() / 1e6:.1f}M params) to {out_dir} "
              f"in {time.time() - t0:.1f}s")
    return meta


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--big", action="store_true",
                    help="emit the ~100M-param geometry instead of tiny")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()
    geom = BigGeometry() if args.big else TINY
    emit(os.path.abspath(args.out_dir), geom,
         skip_existing=args.skip_existing)


if __name__ == "__main__":
    main()
