"""Model geometry and AOT bucket definitions shared by model.py / aot.py / tests.

The serving engine compiles one HLO artifact per static-shape bucket:
  * prefill_n{N}_c{C}: prefill N new tokens against a cached prefix held in
    a KV buffer of capacity C (C == 0 means the no-cache variant).
  * decode_ctx{CTX}:   one decode step against a KV buffer of capacity CTX.
The Rust engine picks the smallest bucket that fits (vLLM-style padding).
"""

from dataclasses import dataclass, field


@dataclass(frozen=True)
class ModelGeometry:
    """Decoder-only transformer geometry (llama-style: RMSNorm/RoPE/SwiGLU)."""

    vocab: int = 2048
    layers: int = 4
    d_model: int = 256
    n_heads: int = 8
    ffn: int = 704          # SwiGLU inner dim (~2.75x d_model)
    max_seq: int = 512
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def param_count(self) -> int:
        d, f, L, v = self.d_model, self.ffn, self.layers, self.vocab
        per_layer = 4 * d * d + 3 * d * f + 2 * d  # attn + mlp + 2 norms
        return v * d * 2 + L * per_layer + d  # embed + unembed + final norm


@dataclass(frozen=True)
class Buckets:
    """Static-shape buckets the AOT pass compiles."""

    prefill_n: tuple = (16, 32, 64, 128, 256)
    cache_c: tuple = (0, 256, 512)
    decode_ctx: tuple = (64, 128, 256, 512)

    def prefill_variants(self, max_seq: int):
        """All (N, C) pairs. C is the *capacity* of the cached-KV input
        buffer (C==0 = no-cache variant); the actual cache_len + new_len
        must fit max_seq at runtime, but a large-capacity bucket with a
        short valid prefix is fine — the engine picks the smallest C >=
        cache_len."""
        return [(n, c) for n in self.prefill_n if n <= max_seq
                for c in self.cache_c if c <= max_seq]


# The canonical geometry used by `make artifacts` and all tests. A larger
# config (configs/model_100m.toml on the Rust side) reuses the same code.
TINY = ModelGeometry()
BUCKETS = Buckets()


@dataclass(frozen=True)
class BigGeometry(ModelGeometry):
    """~100M-param config used by the scale example (compile-only by default)."""

    vocab: int = 8192
    layers: int = 12
    d_model: int = 768
    n_heads: int = 12
    ffn: int = 2048
    max_seq: int = 512
