"""Parameter initialization, deterministic ordering, and binary export.

The Rust runtime never runs Python, so weights are exported once by
``aot.py`` as a flat little-endian f32 blob (``artifacts/weights.bin``)
plus a manifest entry per tensor in ``artifacts/meta.json``. The flatten
order here is the *contract*: every AOT'd HLO takes the weight tensors as
its leading arguments in exactly this order.
"""

import numpy as np

from .geometry import ModelGeometry


def param_order(geom: ModelGeometry):
    """The canonical (name, shape) list — the cross-language ABI."""
    d, f = geom.d_model, geom.ffn
    order = [("embed", (geom.vocab, d))]
    for layer in range(geom.layers):
        p = f"layer{layer}."
        order += [
            (p + "attn_norm", (d,)),
            (p + "wq", (d, d)),
            (p + "wk", (d, d)),
            (p + "wv", (d, d)),
            (p + "wo", (d, d)),
            (p + "mlp_norm", (d,)),
            (p + "w_gate", (d, f)),
            (p + "w_up", (d, f)),
            (p + "w_down", (f, d)),
        ]
    order += [("final_norm", (d,)), ("unembed", (d, geom.vocab))]
    return order


def init_params(geom: ModelGeometry, seed: int = 0x5EED):
    """Deterministic scaled-normal init; returns a list of np.float32
    arrays in ``param_order``. Norm weights init to 1."""
    rng = np.random.default_rng(seed)
    out = []
    for name, shape in param_order(geom):
        if name.endswith("norm"):
            out.append(np.ones(shape, np.float32))
        else:
            scale = 1.0 / np.sqrt(shape[0])
            out.append((rng.standard_normal(shape) * scale)
                       .astype(np.float32))
    return out


def write_weights(geom: ModelGeometry, params, path):
    """Concatenate all tensors (C order) into one f32-LE blob; return the
    manifest [{name, shape, offset_f32, len_f32}] for meta.json."""
    order = param_order(geom)
    assert len(order) == len(params), (len(order), len(params))
    manifest = []
    offset = 0
    with open(path, "wb") as f:
        for (name, shape), arr in zip(order, params):
            assert tuple(arr.shape) == tuple(shape), (name, arr.shape, shape)
            flat = np.ascontiguousarray(arr, np.float32).ravel()
            f.write(flat.astype("<f4").tobytes())
            manifest.append({
                "name": name,
                "shape": list(shape),
                "offset_f32": offset,
                "len_f32": int(flat.size),
            })
            offset += int(flat.size)
    return manifest
