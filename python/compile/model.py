"""L2: the JAX model — a llama-style decoder-only transformer.

Two graph families are AOT-lowered per static-shape bucket (geometry.py):

  prefill(params, tokens[N], new_len, cache_len, kv_cache[L,2,C,H,hd])
      -> (new_kv[L,2,N,H,hd], last_logits[V])
    Prefills N (padded) new tokens against a cached prefix of
    ``cache_len`` valid tokens held in a capacity-C KV buffer. The
    attention hot-spot is the L1 Pallas kernel (prefix_attention).
    ``new_kv`` holds post-RoPE keys — cacheable as-is, which is what lets
    MemServe reuse/transfer KV without reshaping (paper §4.2).

  decode(params, token[1], pos, kv[L,2,CTX,H,hd])
      -> (logits[V], kv_out[L,2,CTX,H,hd])
    One decode step at absolute position ``pos``; writes the new K/V into
    the buffer via dynamic_update_slice so the Rust engine can keep the
    active KV resident as a PJRT buffer across steps (no host round-trip
    on the decode hot loop).

Everything here is build-time only: ``aot.py`` lowers these functions to
HLO text; the Rust runtime executes them.
"""

import jax
import jax.numpy as jnp

from .geometry import ModelGeometry
from .kernels.prefix_attention import prefix_attention


# --------------------------------------------------------------------------
# Building blocks
# --------------------------------------------------------------------------

def rms_norm(x, w, eps):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def rope_tables(geom: ModelGeometry, positions):
    """cos/sin tables [T, hd/2] for absolute ``positions`` (i32[T])."""
    hd = geom.head_dim
    inv_freq = 1.0 / (geom.rope_theta
                      ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    angles = positions.astype(jnp.float32)[:, None] * inv_freq[None, :]
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x, cos, sin):
    """x: [T, H, hd]; rotate pairs (even, odd) by the position angle."""
    x1 = x[..., 0::2]
    x2 = x[..., 1::2]
    c = cos[:, None, :]
    s = sin[:, None, :]
    ro1 = x1 * c - x2 * s
    ro2 = x1 * s + x2 * c
    out = jnp.stack([ro1, ro2], axis=-1)
    return out.reshape(x.shape)


def unpack_params(geom: ModelGeometry, params):
    """params: flat list in params.param_order -> structured dict."""
    it = iter(params)
    p = {"embed": next(it), "layers": []}
    for _ in range(geom.layers):
        p["layers"].append({
            "attn_norm": next(it), "wq": next(it), "wk": next(it),
            "wv": next(it), "wo": next(it), "mlp_norm": next(it),
            "w_gate": next(it), "w_up": next(it), "w_down": next(it),
        })
    p["final_norm"] = next(it)
    p["unembed"] = next(it)
    rest = list(it)
    assert not rest, f"{len(rest)} unconsumed params"
    return p


def _qkv(geom, x, lp, positions):
    """Project + RoPE. x: [T, d] -> q/k/v [H, T, hd] (k post-RoPE)."""
    t = x.shape[0]
    heads, hd = geom.n_heads, geom.head_dim
    q = (x @ lp["wq"]).reshape(t, heads, hd)
    k = (x @ lp["wk"]).reshape(t, heads, hd)
    v = (x @ lp["wv"]).reshape(t, heads, hd)
    cos, sin = rope_tables(geom, positions)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    # [T, H, hd] -> [H, T, hd] (kernel layout)
    return (q.transpose(1, 0, 2), k.transpose(1, 0, 2), v.transpose(1, 0, 2))


def _mlp(x, lp):
    gate = jax.nn.silu(x @ lp["w_gate"])
    return (gate * (x @ lp["w_up"])) @ lp["w_down"]


# --------------------------------------------------------------------------
# Prefill graph
# --------------------------------------------------------------------------

def prefill(geom: ModelGeometry, params, tokens, new_len, cache_len,
            kv_cache=None, *, interpret=True):
    """See module docstring. kv_cache is None for the C==0 bucket."""
    p = unpack_params(geom, params)
    n = tokens.shape[0]
    heads, hd = geom.n_heads, geom.head_dim
    cl = cache_len.reshape(())
    nl = new_len.reshape(())
    cl_arr = cache_len.reshape((1,))
    nl_arr = new_len.reshape((1,))

    positions = cl + jnp.arange(n, dtype=jnp.int32)
    x = p["embed"][tokens]                          # [N, d]

    new_kv_layers = []
    for li in range(geom.layers):
        lp = p["layers"][li]
        h = rms_norm(x, lp["attn_norm"], geom.norm_eps)
        q, k, v = _qkv(geom, h, lp, positions)       # [H, N, hd]
        if kv_cache is not None:
            k_cache = kv_cache[li, 0].transpose(1, 0, 2)  # [C,H,hd]->[H,C,hd]
            v_cache = kv_cache[li, 1].transpose(1, 0, 2)
        else:
            k_cache = jnp.zeros((heads, 0, hd), x.dtype)
            v_cache = k_cache
        attn = prefix_attention(q, k_cache, v_cache, k, v, cl_arr, nl_arr,
                                interpret=interpret)  # [H, N, hd]
        attn = attn.transpose(1, 0, 2).reshape(n, geom.d_model)
        x = x + attn @ lp["wo"]
        h = rms_norm(x, lp["mlp_norm"], geom.norm_eps)
        x = x + _mlp(h, lp)
        # Cacheable layout [2, N, H, hd]: post-RoPE keys, raw values.
        new_kv_layers.append(jnp.stack(
            [k.transpose(1, 0, 2), v.transpose(1, 0, 2)]))

    new_kv = jnp.stack(new_kv_layers)               # [L, 2, N, H, hd]
    x = rms_norm(x, p["final_norm"], geom.norm_eps)
    last = jax.lax.dynamic_slice_in_dim(
        x, jnp.maximum(nl - 1, 0), 1, axis=0)[0]    # [d]
    logits = last @ p["unembed"]                    # [V]
    return new_kv, logits


# --------------------------------------------------------------------------
# Decode graph
# --------------------------------------------------------------------------

def decode(geom: ModelGeometry, params, token, pos, kv):
    """One decode step. token i32[1], pos i32[] (absolute position of this
    token), kv f32[L,2,CTX,H,hd] with positions [0,pos) valid.

    Returns (logits[V], kv_out) where kv_out has this token's K/V written
    at index ``pos``. Decode attention is a masked jnp computation — it is
    a memory-bound GEMV-scale op; the Pallas kernel targets the prefill
    hot-spot (see DESIGN.md §4).
    """
    p = unpack_params(geom, params)
    ctx = kv.shape[2]
    heads, hd = geom.n_heads, geom.head_dim
    pos = pos.reshape(())
    positions = pos.reshape((1,))

    x = p["embed"][token]                           # [1, d]
    kv_out = kv
    col = jnp.arange(ctx)
    for li in range(geom.layers):
        lp = p["layers"][li]
        h = rms_norm(x, lp["attn_norm"], geom.norm_eps)
        q, k, v = _qkv(geom, h, lp, positions)       # [H, 1, hd]
        # Write K/V at position pos: kv_out[li, 0, pos] = k
        k_t = k.transpose(1, 0, 2)                   # [1, H, hd]
        v_t = v.transpose(1, 0, 2)
        kv_out = jax.lax.dynamic_update_slice(
            kv_out, jnp.stack([k_t, v_t])[None, :],  # [1, 2, 1, H, hd]
            (li, 0, pos, 0, 0))
        k_all = kv_out[li, 0].transpose(1, 0, 2)     # [H, CTX, hd]
        v_all = kv_out[li, 1].transpose(1, 0, 2)
        s = jnp.einsum("hqd,hkd->hqk", q, k_all) / jnp.sqrt(
            jnp.asarray(hd, jnp.float32))
        s = jnp.where((col <= pos)[None, None, :], s, -1e30)
        w = jax.nn.softmax(s, axis=-1)
        attn = jnp.einsum("hqk,hkd->hqd", w, v_all)  # [H, 1, hd]
        attn = attn.transpose(1, 0, 2).reshape(1, geom.d_model)
        x = x + attn @ lp["wo"]
        h = rms_norm(x, lp["mlp_norm"], geom.norm_eps)
        x = x + _mlp(h, lp)

    x = rms_norm(x, p["final_norm"], geom.norm_eps)
    logits = (x @ p["unembed"])[0]                  # [V]
    return logits, kv_out


def decode_state(geom: ModelGeometry, params, token, pos, state, ctx: int):
    """Flat-state decode step for the Rust engine's zero-copy hot loop.

    ``state`` is f32[vocab + L*2*ctx*H*hd]: the logits region (ignored on
    input) followed by the KV buffer. Returning one flat array (lowered
    with return_tuple=False) makes the PJRT output a single non-tuple
    buffer the engine feeds straight back as the next step's input —
    active KV never leaves the device during decode; only the 4·vocab-byte
    logits region is read back per step (offset read).
    """
    kv_len = geom.layers * 2 * ctx * geom.n_heads * geom.head_dim
    kv = state[geom.vocab:geom.vocab + kv_len].reshape(
        (geom.layers, 2, ctx, geom.n_heads, geom.head_dim))
    logits, kv_out = decode(geom, params, token, pos, kv)
    return jnp.concatenate([logits, kv_out.reshape(-1)])
